//! The implementation architecture (paper Fig 18): N SF-MMCN units, TOP
//! CTRL, input/weight buffers, pooling and activation units — driven layer
//! by layer over a [`ModelGraph`], producing *both* the functional output
//! (16-bit fixed-point numerics) and the cycle/energy event counts.
//!
//! Mapping rules (paper §III.D, §IV.B):
//! * Output channels are distributed round-robin over the *active* units;
//!   units process 8 spatially-adjacent outputs per group (PE_1..PE_8).
//! * The number of active units is capacity-limited by the input-channel
//!   broadcast: `units_active = min(units, 2 * c_in)` — this is the
//!   paper's "only 6 of the proposed SF-MMCN are set to execute" for the
//!   3-channel first layer (Fig 21).
//! * Layer wall-cycles = max over units (they run lock-step in silicon);
//!   a unit's PEs idle-clock while other units finish.
//! * Residual skips and U-net time-dense layers ride on PE_9 (see
//!   [`super::unit`]), so parallel branches add no cycles.
//!
//! §Perf — two code paths compute identical results:
//! * [`Accelerator::run_graph`] is the flat-buffer hot path: per-layer
//!   window slabs and zero counts built once and shared across output
//!   channels, per-node quantized weight caches ([`WeightStore`]), flat
//!   group execution ([`SfMmcnUnit::run_group_flat`]), and the lock-step
//!   units mapped onto `std::thread::scope` threads (one per unit, each
//!   owning a disjoint round-robin slice of output channels).
//! * [`Accelerator::run_graph_ref`] is the scalar reference
//!   implementation (the pre-optimization seed code path), kept as the
//!   bit-exactness oracle: `rust/tests/sim_golden.rs` pins outputs, wall
//!   cycles, and every event counter of the two paths against each other,
//!   and `benches/hotpath.rs` uses it as the speedup baseline.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::models::graph::{Act, Layer, ModelGraph, Node, Residual};
use crate::quant::{quantize, Fixed};
use crate::util::{Rng, Tensor};

use super::energy::EventCounts;
use super::memory::MemorySystem;
use super::pe::count_zeros;
use super::unit::{ConvGroup, FlatServer, ServerTask, SfMmcnUnit, PES_PER_UNIT, WORKERS};

/// Static configuration of the accelerator instance.
#[derive(Debug, Clone, Copy)]
pub struct AcceleratorConfig {
    /// Number of SF-MMCN units (paper sweeps 2/4/8/16; ships 8).
    pub units: usize,
    /// Input-buffer capacity in 16-bit elements.
    pub input_buf_elems: u64,
    /// Weight-buffer capacity in 16-bit elements.
    pub weight_buf_elems: u64,
    /// Zero-gate unit enabled (energy only; always true on the real chip).
    pub zero_gate: bool,
    /// SF data-reuse registers enabled (ablation toggle).
    pub data_reuse: bool,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self {
            units: 8,
            // Capacities are in 16-bit *elements*, not bytes: 64 Ki
            // elements = 128 KiB input buffer, 16 Ki elements = 32 KiB
            // weight buffer (the paper's on-chip SRAM sizing).
            input_buf_elems: 64 * 1024,
            weight_buf_elems: 16 * 1024,
            zero_gate: true,
            data_reuse: true,
        }
    }
}

impl AcceleratorConfig {
    pub fn with_units(units: usize) -> Self {
        Self {
            units,
            ..Self::default()
        }
    }

    pub fn total_pes(&self) -> u64 {
        (self.units * PES_PER_UNIT) as u64
    }
}

/// Per-node simulation result.
#[derive(Debug, Clone)]
pub struct LayerRun {
    pub node_idx: usize,
    pub label: String,
    /// Wall cycles for this node.
    pub cycles: u64,
    /// Aggregated events for this node (cycles field == wall cycles).
    pub counts: EventCounts,
    /// PE utilization for this node (fraction).
    pub u_pe: f64,
    /// Model MACs this node performed.
    pub macs: u64,
}

/// Full-graph simulation result.
#[derive(Debug, Clone)]
pub struct GraphRun {
    pub output: Tensor,
    pub layers: Vec<LayerRun>,
    pub totals: EventCounts,
}

impl GraphRun {
    pub fn total_cycles(&self) -> u64 {
        self.totals.cycles
    }
}

/// Per-node weights (f32 master copies; quantized at the datapath edge).
#[derive(Debug, Clone)]
pub struct NodeWeights {
    /// Conv: `[c_out, c_in, k, k]`; Dense: `[out_f, in_f]`.
    pub w: Tensor,
    /// Bias per output channel / neuron.
    pub bias: Vec<f32>,
    /// Residual 1x1 conv weights `[c_out, c_in_skip]` (Residual::Conv).
    pub w_res: Option<Tensor>,
    /// Time-dense weights `[c_out, time_dim]`.
    pub w_time: Option<Tensor>,
}

/// Quantized (Q8.8) weight taps for one node, flat so the hot path can
/// slice per output channel without re-quantizing (§Perf: built once per
/// node and cached in [`WeightStore`], reused across runs and groups).
#[derive(Debug)]
pub struct NodeQuant {
    /// Conv: `[c_out, c_in*k*k]`; Dense: `[out_f, in_f]` — the weight
    /// tensor's natural row-major order, i.e. `quantize(w.data())`.
    pub w: Vec<Fixed>,
    /// Dense nodes only: zero taps per weight row. (The dense mapping
    /// broadcasts the input and streams weight rows through the worker
    /// windows, so the zero-gate counts zeros of the *weights* there.)
    pub w_zeros: Vec<u64>,
    /// Residual 1x1 conv taps `[c_out, c_skip]`.
    pub w_res: Option<Vec<Fixed>>,
    /// Time-dense taps `[c_out, time_dim]`.
    pub w_time: Option<Vec<Fixed>>,
}

impl NodeQuant {
    /// Quantize a node's weights. `dense_rows = Some((rows, row_len))`
    /// additionally precomputes per-row zero counts for dense layers.
    fn new(nw: &NodeWeights, dense_rows: Option<(usize, usize)>) -> Self {
        let w = quantize(nw.w.data());
        let w_zeros = match dense_rows {
            Some((rows, row_len)) => (0..rows)
                .map(|r| count_zeros(&w[r * row_len..(r + 1) * row_len]))
                .collect(),
            None => Vec::new(),
        };
        Self {
            w,
            w_zeros,
            w_res: nw.w_res.as_ref().map(|t| quantize(t.data())),
            w_time: nw.w_time.as_ref().map(|t| quantize(t.data())),
        }
    }
}

/// Lazily-built per-node quantized weight cache (interior mutability so
/// `run_graph` can fill it through a shared reference).
#[derive(Debug, Clone, Default)]
struct QuantCache {
    slots: RefCell<Vec<Option<Arc<NodeQuant>>>>,
}

/// All weights for a graph, deterministically initialized (He-style).
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub per_node: Vec<Option<NodeWeights>>,
    quant: QuantCache,
}

impl WeightStore {
    /// Wrap an explicit per-node weight list (quantized caches empty).
    pub fn from_nodes(per_node: Vec<Option<NodeWeights>>) -> Self {
        Self {
            per_node,
            quant: QuantCache::default(),
        }
    }

    /// Drop all cached quantized taps. Call after mutating `per_node` in
    /// place once the store has been used in a run — the cache is keyed
    /// by node index only and cannot see in-place weight edits.
    pub fn invalidate_quant(&self) {
        self.quant.slots.borrow_mut().clear();
    }

    /// Quantized taps for node `idx`, built on first use and cached.
    /// `dense_rows` must be `Some((rows, row_len))` for dense nodes.
    fn quantized(
        &self,
        idx: usize,
        dense_rows: Option<(usize, usize)>,
    ) -> Option<Arc<NodeQuant>> {
        let nw = self.per_node[idx].as_ref()?;
        let mut slots = self.quant.slots.borrow_mut();
        if slots.len() < self.per_node.len() {
            slots.resize(self.per_node.len(), None);
        }
        if slots[idx].is_none() {
            slots[idx] = Some(Arc::new(NodeQuant::new(nw, dense_rows)));
        }
        slots[idx].clone()
    }

    pub fn random(g: &ModelGraph, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut per_node = Vec::with_capacity(g.nodes.len());
        for n in &g.nodes {
            let nw = match &n.layer {
                Layer::Conv {
                    c_in,
                    c_out,
                    k,
                    residual,
                    time_dense,
                    ..
                } => {
                    let fan_in = (c_in * k * k) as f32;
                    let scale = (2.0 / fan_in).sqrt();
                    let w = Tensor::from_fn(&[*c_out, *c_in, *k, *k], |_| {
                        rng.normal() * scale
                    });
                    let bias = (0..*c_out).map(|_| rng.normal() * 0.01).collect();
                    let w_res = match residual {
                        Residual::Conv { from: _, .. } => {
                            let c_skip = n.in_shape.c; // checked at exec time
                            let _ = c_skip;
                            None // filled at exec time when skip shape known
                        }
                        _ => None,
                    };
                    let w_time = time_dense.map(|td| {
                        let s = (2.0 / td as f32).sqrt();
                        Tensor::from_fn(&[*c_out, td], |_| rng.normal() * s)
                    });
                    Some(NodeWeights {
                        w,
                        bias,
                        w_res,
                        w_time,
                    })
                }
                Layer::Dense { in_f, out_f, .. } => {
                    let scale = (2.0 / *in_f as f32).sqrt();
                    let w = Tensor::from_fn(&[*out_f, *in_f], |_| rng.normal() * scale);
                    let bias = (0..*out_f).map(|_| rng.normal() * 0.01).collect();
                    Some(NodeWeights {
                        w,
                        bias,
                        w_res: None,
                        w_time: None,
                    })
                }
                _ => None,
            };
            per_node.push(nw);
        }
        // Second pass: residual-conv weights need the *skip source* channel
        // count, which is the conv's in_shape only for stride-1 same-c
        // cases; derive from the referenced node's out_shape.
        let mut ws = Self::from_nodes(per_node);
        let mut rng2 = Rng::new(seed ^ 0xABCD_EF01);
        for (i, n) in g.nodes.iter().enumerate() {
            if let Layer::Conv {
                c_out,
                residual: Residual::Conv { from, .. },
                ..
            } = &n.layer
            {
                let c_skip = g.nodes[*from].out_shape.c;
                let scale = (2.0 / c_skip as f32).sqrt();
                let w = Tensor::from_fn(&[*c_out, c_skip], |_| rng2.normal() * scale);
                ws.per_node[i].as_mut().unwrap().w_res = Some(w);
            }
        }
        ws
    }
}

/// Distinct input-buffer reads for one conv group starting at flattened
/// output position `p` with `gw` lanes (row-major, groups may wrap rows).
///
/// With the SF reuse registers and stride 1, a row-continuing segment only
/// fetches its new columns; a segment that starts a row fetches `k-1`
/// extra edge columns. Strided or reuse-less convs fetch every tap.
/// Shared by the micro simulator and the analytic schedule model so the
/// two cannot drift.
pub fn conv_group_distinct(
    c_in: usize,
    k: usize,
    stride: usize,
    data_reuse: bool,
    p: usize,
    gw: usize,
    w_out: usize,
) -> u64 {
    let total = (gw * k * k * c_in) as u64;
    if !data_reuse || stride != 1 {
        return total;
    }
    // split [p, p+gw) into row segments
    let mut cols = 0usize;
    let mut q = p;
    let end = p + gw;
    while q < end {
        let ox = q % w_out;
        let seg = (w_out - ox).min(end - q);
        // a segment starting at column 0 begins a fresh row
        cols += if ox == 0 { k - 1 + seg } else { seg };
        q += seg;
    }
    ((c_in * k * cols) as u64).min(total)
}

/// Threading threshold: conv/dense layers with at least this many MAC
/// tap-slots fan their units out over scoped threads; smaller layers run
/// inline (spawn overhead would dominate). Either way results and event
/// counts are identical — the threshold only moves wall-clock.
const PAR_MIN_TAP_SLOTS: u64 = 1 << 18;

/// One unit's contribution to a layer, merged after the layer barrier.
struct UnitOutcome {
    cycles: u64,
    skip_reads: u64,
}

/// Read-only per-layer execution plan shared by every unit (and thread)
/// of a conv layer: flat window slab + zero counts + group table built
/// once, quantized weights sliced per output channel.
struct ConvPlan<'a> {
    taps: usize,
    hw: usize,
    act: Act,
    residual: Residual,
    /// `(start position, lane count, reused inputs)` per group.
    groups: &'a [(usize, usize, u64)],
    /// `hw x taps` window slab.
    windows: &'a [Fixed],
    /// Zero taps per window position.
    win_zeros: &'a [u64],
    /// Main conv taps `[c_out, taps]`.
    qw: &'a [Fixed],
    bias: &'a [f32],
    /// Identity skip, quantized `[c_out, hw]` (empty otherwise).
    sq: &'a [Fixed],
    /// Conv-skip windows `[hw, c_skip]` (empty otherwise).
    rwin: &'a [Fixed],
    rzeros: &'a [u64],
    c_skip: usize,
    qw_res: Option<&'a [Fixed]>,
    emb: Option<&'a [Fixed]>,
    emb_zeros: u64,
    qw_time: Option<&'a [Fixed]>,
}

/// Read-only per-layer plan for a dense node (neuron groups of 8).
struct DensePlan<'a> {
    in_f: usize,
    act: Act,
    /// Weight rows `[out_f, in_f]` — these are the worker *windows*.
    qw: &'a [Fixed],
    w_zeros: &'a [u64],
    /// Quantized input vector — broadcast as the shared filter.
    xq: &'a [Fixed],
    bias: &'a [f32],
}

/// The simulated accelerator.
pub struct Accelerator {
    pub cfg: AcceleratorConfig,
    units: Vec<SfMmcnUnit>,
    pub mem: MemorySystem,
}

impl Accelerator {
    pub fn new(cfg: AcceleratorConfig) -> Self {
        assert!(cfg.units >= 1);
        Self {
            cfg,
            units: (0..cfg.units).map(|_| SfMmcnUnit::new()).collect(),
            mem: MemorySystem::new(cfg.input_buf_elems, cfg.weight_buf_elems),
        }
    }

    /// Active units for a conv layer: broadcast-bandwidth-limited by the
    /// input channel count (paper: 3-channel first layer runs 6 of 8).
    fn active_units(&self, c_in: usize) -> usize {
        self.cfg.units.min(2 * c_in).max(1)
    }

    fn snapshot(
        &self,
    ) -> (
        Vec<super::unit::UnitStats>,
        Vec<(super::pe::PeStats, super::pe::PeStats)>,
    ) {
        (
            self.units.iter().map(|u| u.stats).collect(),
            self.units.iter().map(|u| u.pe_stats()).collect(),
        )
    }

    /// Diff unit/PE stats since `snap` into an EventCounts with the given
    /// wall cycles.
    fn delta_counts(
        &self,
        snap: &(
            Vec<super::unit::UnitStats>,
            Vec<(super::pe::PeStats, super::pe::PeStats)>,
        ),
        wall_cycles: u64,
        mem_before: super::memory::MemoryStats,
    ) -> EventCounts {
        let mut c = EventCounts {
            cycles: wall_cycles,
            total_pes: self.cfg.total_pes(),
            ..Default::default()
        };
        for (i, u) in self.units.iter().enumerate() {
            let prev = &snap.0[i];
            c.unit.cycles += u.stats.cycles - prev.cycles;
            c.unit.conv_outputs += u.stats.conv_outputs - prev.conv_outputs;
            c.unit.served_values += u.stats.served_values - prev.served_values;
            c.unit.buffer_reads += u.stats.buffer_reads - prev.buffer_reads;
            c.unit.buffer_reads_no_reuse +=
                u.stats.buffer_reads_no_reuse - prev.buffer_reads_no_reuse;
            c.unit.weight_reads += u.stats.weight_reads - prev.weight_reads;
            c.unit.reuse_reg_writes += u.stats.reuse_reg_writes - prev.reuse_reg_writes;
            let (w, s) = u.pe_stats();
            let (pw, ps) = &snap.1[i];
            c.pe.active_cycles += (w.active_cycles - pw.active_cycles)
                + (s.active_cycles - ps.active_cycles);
            c.pe.idle_cycles +=
                (w.idle_cycles - pw.idle_cycles) + (s.idle_cycles - ps.idle_cycles);
            c.pe.macs += (w.macs - pw.macs) + (s.macs - ps.macs);
            c.pe.gated_macs += (w.gated_macs - pw.gated_macs) + (s.gated_macs - ps.gated_macs);
            c.pe.residual_adds +=
                (w.residual_adds - pw.residual_adds) + (s.residual_adds - ps.residual_adds);
            c.pe.writebacks += (w.writebacks - pw.writebacks) + (s.writebacks - ps.writebacks);
        }
        c.mem = self.mem.stats.since(&mem_before);
        c
    }

    /// Run a whole graph on the §Perf flat-buffer hot path. `time_emb`
    /// supplies the U-net time embedding (required iff the graph has
    /// `time_dense` convs). Outputs, wall cycles, and event counts are
    /// bit-identical to [`Self::run_graph_ref`].
    pub fn run_graph(
        &mut self,
        g: &ModelGraph,
        input: &Tensor,
        weights: &WeightStore,
        time_emb: Option<&[f32]>,
    ) -> Result<GraphRun> {
        self.run_graph_inner(g, input, weights, time_emb, false)
    }

    /// Run a whole graph on the scalar *reference* path — the seed
    /// implementation preserved verbatim as the bit-exactness oracle and
    /// the perf baseline (`tests/sim_golden.rs`, `benches/hotpath.rs`).
    pub fn run_graph_ref(
        &mut self,
        g: &ModelGraph,
        input: &Tensor,
        weights: &WeightStore,
        time_emb: Option<&[f32]>,
    ) -> Result<GraphRun> {
        self.run_graph_inner(g, input, weights, time_emb, true)
    }

    fn run_graph_inner(
        &mut self,
        g: &ModelGraph,
        input: &Tensor,
        weights: &WeightStore,
        time_emb: Option<&[f32]>,
        reference: bool,
    ) -> Result<GraphRun> {
        if input.shape() != [g.input.c, g.input.h, g.input.w] {
            bail!(
                "input shape {:?} != graph input {:?}",
                input.shape(),
                g.input
            );
        }
        // §Perf: only outputs a later node consumes as a skip/concat
        // source are retained — everything else moves through the
        // double-buffered `cur` with no per-layer clone traffic.
        let mut needed = vec![false; g.nodes.len()];
        for n in &g.nodes {
            match &n.layer {
                Layer::Conv { residual, .. } => match residual {
                    Residual::Identity { from } | Residual::Conv { from, .. }
                        if *from != usize::MAX =>
                    {
                        needed[*from] = true;
                    }
                    _ => {}
                },
                Layer::ConcatSkip { from } => needed[*from] = true,
                _ => {}
            }
        }

        let mut outputs: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
        let mut layers = Vec::with_capacity(g.nodes.len());
        let mut totals = EventCounts {
            total_pes: self.cfg.total_pes(),
            ..Default::default()
        };

        let mut cur = input.clone();
        for (idx, node) in g.nodes.iter().enumerate() {
            let snap = self.snapshot();
            let mem_before = self.mem.stats;
            let (out, wall, label) = match &node.layer {
                Layer::Conv { residual, .. } => {
                    let skip: Option<&Tensor> = match residual {
                        Residual::None => None,
                        Residual::Identity { from } | Residual::Conv { from, .. } => {
                            if *from == usize::MAX {
                                Some(input)
                            } else {
                                Some(
                                    outputs[*from]
                                        .as_ref()
                                        .context("skip source not materialized")?,
                                )
                            }
                        }
                    };
                    let nw = weights.per_node[idx]
                        .as_ref()
                        .context("conv node missing weights")?;
                    if reference {
                        self.run_conv_ref(node, &cur, nw, skip, time_emb)?
                    } else {
                        let nq = weights
                            .quantized(idx, None)
                            .context("conv node missing weights")?;
                        self.run_conv(node, &cur, nw, &nq, skip, time_emb)?
                    }
                }
                Layer::MaxPool { k, stride } => self.run_maxpool(node, &cur, *k, *stride),
                Layer::GlobalAvgPool => self.run_gap(node, &cur),
                Layer::Dense { in_f, out_f, act } => {
                    let nw = weights.per_node[idx]
                        .as_ref()
                        .context("dense node missing weights")?;
                    if reference {
                        self.run_dense_ref(node, &cur, nw, *act)?
                    } else {
                        let nq = weights
                            .quantized(idx, Some((*out_f, *in_f)))
                            .context("dense node missing weights")?;
                        self.run_dense(node, &cur, nw, &nq, *act)?
                    }
                }
                Layer::Upsample2x => self.run_upsample(node, &cur),
                Layer::ConcatSkip { from } => {
                    let skip = outputs[*from]
                        .as_ref()
                        .context("concat skip source not materialized")?;
                    self.run_concat(node, &cur, skip)?
                }
            };
            let counts = self.delta_counts(&snap, wall, mem_before);
            let u_pe = counts.u_pe();
            totals.accumulate(&counts);
            layers.push(LayerRun {
                node_idx: idx,
                label,
                cycles: wall,
                macs: node.macs(),
                counts,
                u_pe,
            });
            // Retain only skip/concat sources; `cur` double-buffers the
            // rest (the memory *system* accounting is what matters, not
            // host RAM — but the host clones were the sim's hot path).
            if needed[idx] {
                outputs[idx] = Some(out.clone());
            }
            cur = out;
            // New layer: the unit pipelines drain.
            for u in &mut self.units {
                u.flush_pipeline();
            }
        }

        Ok(GraphRun {
            output: cur,
            layers,
            totals,
        })
    }

    /// Extract an input window (with zero padding) as quantized taps,
    /// channel-major: for each input channel, k x k values. Reference-path
    /// variant appending into a scratch `Vec` (see [`Self::fill_window_into`]
    /// for the flat hot path).
    #[allow(clippy::too_many_arguments)]
    fn fill_window(
        xq: &[Fixed],
        h: usize,
        w: usize,
        oy: usize,
        ox: usize,
        k: usize,
        stride: usize,
        pad: usize,
        c_in: usize,
        out: &mut Vec<Fixed>,
    ) {
        out.clear();
        let plane = h * w;
        for c in 0..c_in {
            let base_c = c * plane;
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    out.extend(std::iter::repeat_n(Fixed::ZERO, k));
                    continue;
                }
                let row = base_c + iy as usize * w;
                let x0 = (ox * stride) as isize - pad as isize;
                if x0 >= 0 && x0 as usize + k <= w {
                    // interior row: one contiguous copy (the common case)
                    let s = row + x0 as usize;
                    out.extend_from_slice(&xq[s..s + k]);
                } else {
                    for kx in 0..k {
                        let ix = x0 + kx as isize;
                        out.push(if ix < 0 || ix >= w as isize {
                            Fixed::ZERO
                        } else {
                            xq[row + ix as usize]
                        });
                    }
                }
            }
        }
    }

    /// Flat-slab variant of [`Self::fill_window`]: writes the window into
    /// a caller-provided `c_in*k*k` slice and returns its zero-tap count.
    /// §Perf: the hot path builds the whole layer's windows (and counts)
    /// once and shares them across every output channel — the seed
    /// re-extracted them `c_out` times.
    #[allow(clippy::too_many_arguments)]
    fn fill_window_into(
        xq: &[Fixed],
        h: usize,
        w: usize,
        oy: usize,
        ox: usize,
        k: usize,
        stride: usize,
        pad: usize,
        c_in: usize,
        out: &mut [Fixed],
    ) -> u64 {
        debug_assert_eq!(out.len(), c_in * k * k);
        let plane = h * w;
        let mut cursor = 0usize;
        for c in 0..c_in {
            let base_c = c * plane;
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad as isize;
                let dst = &mut out[cursor..cursor + k];
                cursor += k;
                if iy < 0 || iy >= h as isize {
                    dst.fill(Fixed::ZERO);
                    continue;
                }
                let row = base_c + iy as usize * w;
                let x0 = (ox * stride) as isize - pad as isize;
                if x0 >= 0 && x0 as usize + k <= w {
                    // interior row: one contiguous copy (the common case)
                    let s = row + x0 as usize;
                    dst.copy_from_slice(&xq[s..s + k]);
                } else {
                    for (kx, d) in dst.iter_mut().enumerate() {
                        let ix = x0 + kx as isize;
                        *d = if ix < 0 || ix >= w as isize {
                            Fixed::ZERO
                        } else {
                            xq[row + ix as usize]
                        };
                    }
                }
            }
        }
        count_zeros(out)
    }

    /// Quantize a whole feature map once (layer-level; see `fill_window`).
    fn quantize_map(x: &Tensor) -> Vec<Fixed> {
        x.data().iter().map(|&v| Fixed::from_f32(v)).collect()
    }

    /// Conv filter taps for one output channel, channel-major to match
    /// the window extraction order.
    fn filter(w: &Tensor, oc: usize, c_in: usize, k: usize) -> Vec<Fixed> {
        let mut taps = Vec::with_capacity(c_in * k * k);
        for c in 0..c_in {
            for ky in 0..k {
                for kx in 0..k {
                    taps.push(Fixed::from_f32(w.get(&[oc, c, ky, kx])));
                }
            }
        }
        taps
    }

    fn apply_act(v: f32, act: Act) -> f32 {
        match act {
            Act::None => v,
            Act::Relu => v.max(0.0),
            Act::Silu => v / (1.0 + (-v).exp()),
        }
    }

    /// Label for a conv layer (shared by both code paths).
    fn conv_label(node: &Node, split: bool) -> String {
        let (c_in, c_out, k, stride, residual, time_dense) = match &node.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                stride,
                residual,
                time_dense,
                ..
            } => (*c_in, *c_out, *k, *stride, *residual, *time_dense),
            _ => unreachable!(),
        };
        format!(
            "conv{k}x{k}/{stride} {}x{}x{} -> {}x{}x{}{}{}{}",
            c_in,
            node.in_shape.h,
            node.in_shape.w,
            c_out,
            node.out_shape.h,
            node.out_shape.w,
            match residual {
                Residual::None => "",
                Residual::Identity { .. } => " +skip",
                Residual::Conv { .. } => " +skipconv",
            },
            if time_dense.is_some() { " +time" } else { "" },
            if split { " [split]" } else { "" }
        )
    }

    /// §Perf hot path: execute one conv node from flat per-layer buffers
    /// across the active units, on scoped threads for large layers.
    fn run_conv(
        &mut self,
        node: &Node,
        x: &Tensor,
        nw: &NodeWeights,
        nq: &NodeQuant,
        skip: Option<&Tensor>,
        time_emb: Option<&[f32]>,
    ) -> Result<(Tensor, u64, String)> {
        let (c_in, c_out, k, stride, pad, act, residual, time_dense) = match &node.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                stride,
                pad,
                act,
                residual,
                time_dense,
            } => (
                *c_in, *c_out, *k, *stride, *pad, *act, *residual, *time_dense,
            ),
            _ => unreachable!(),
        };
        if time_dense.is_some() && !matches!(residual, Residual::None) {
            bail!("a conv cannot host both time-dense and a residual on PE_9");
        }
        let out_shape = node.out_shape;
        let hw = out_shape.h * out_shape.w;
        if hw <= 4 && c_out >= 2 {
            // Tiny maps take the small-input split path (Figs 11-12),
            // shared verbatim with the reference implementation.
            return self.run_conv_ref(node, x, nw, skip, time_emb);
        }

        let active = self.active_units(c_in);
        let taps = c_in * k * k;

        // Time-embedding operands, quantized once per layer.
        let t_emb_fx: Option<Vec<Fixed>> = match (time_dense, time_emb) {
            (Some(td), Some(e)) => {
                if e.len() != td {
                    bail!("time embedding len {} != layer's {}", e.len(), td);
                }
                Some(e.iter().map(|&v| Fixed::from_f32(v)).collect())
            }
            (Some(_), None) => bail!("graph needs a time embedding, none supplied"),
            _ => None,
        };
        let emb_zeros = t_emb_fx.as_deref().map(count_zeros).unwrap_or(0);

        // ---- per-layer plan: window slab, zero counts, group table and
        // residual operands built ONCE and shared by every output channel
        // (the seed re-extracted windows c_out times) -------------------
        let xq = Self::quantize_map(x);
        let (h_in, w_in) = (x.shape()[1], x.shape()[2]);
        let mut windows = vec![Fixed::ZERO; hw * taps];
        let mut win_zeros = vec![0u64; hw];
        for p in 0..hw {
            let (oy, ox) = (p / out_shape.w, p % out_shape.w);
            win_zeros[p] = Self::fill_window_into(
                &xq,
                h_in,
                w_in,
                oy,
                ox,
                k,
                stride,
                pad,
                c_in,
                &mut windows[p * taps..(p + 1) * taps],
            );
        }
        let mut groups: Vec<(usize, usize, u64)> =
            Vec::with_capacity(hw.div_ceil(WORKERS));
        let mut p = 0usize;
        while p < hw {
            let gw = WORKERS.min(hw - p);
            let total_inputs = (gw * taps) as u64;
            let reused = total_inputs
                - conv_group_distinct(c_in, k, stride, self.cfg.data_reuse, p, gw, out_shape.w)
                    .min(total_inputs);
            groups.push((p, gw, reused));
            p += gw;
        }

        let mut sq: Vec<Fixed> = Vec::new();
        let mut rwin: Vec<Fixed> = Vec::new();
        let mut rzeros: Vec<u64> = Vec::new();
        let mut c_skip = 0usize;
        match residual {
            Residual::None => {}
            Residual::Identity { .. } => {
                let s = skip.context("identity residual needs skip")?;
                sq = Self::quantize_map(s);
            }
            Residual::Conv {
                stride: rstride, ..
            } => {
                let s = skip.context("conv residual needs skip")?;
                c_skip = s.shape()[0];
                let (sh, sw) = (s.shape()[1], s.shape()[2]);
                let sqs = Self::quantize_map(s);
                rwin = vec![Fixed::ZERO; hw * c_skip];
                rzeros = vec![0u64; hw];
                for q in 0..hw {
                    let (oy, ox) = (q / out_shape.w, q % out_shape.w);
                    let src = oy * rstride * sw + ox * rstride;
                    let dst = &mut rwin[q * c_skip..(q + 1) * c_skip];
                    for (c, d) in dst.iter_mut().enumerate() {
                        *d = sqs[c * sh * sw + src];
                    }
                    rzeros[q] = count_zeros(dst);
                }
            }
        }

        let plan = ConvPlan {
            taps,
            hw,
            act,
            residual,
            groups: &groups,
            windows: &windows,
            win_zeros: &win_zeros,
            qw: &nq.w,
            bias: &nw.bias,
            sq: &sq,
            rwin: &rwin,
            rzeros: &rzeros,
            c_skip,
            qw_res: nq.w_res.as_deref(),
            emb: t_emb_fx.as_deref(),
            emb_zeros,
            qw_time: nq.w_time.as_deref(),
        };

        // ---- execute: lock-step units on scoped threads ----------------
        // Each unit owns a disjoint round-robin slice of output channels
        // (oc % active == unit index, as in silicon), so per-unit stats
        // and output planes merge deterministically at the layer barrier.
        let mut out = Tensor::zeros(&[out_shape.c, out_shape.h, out_shape.w]);
        let mut lanes: Vec<Vec<(usize, &mut [f32])>> =
            (0..active).map(|_| Vec::new()).collect();
        for (oc, plane) in out.data_mut().chunks_mut(hw).enumerate() {
            lanes[oc % active].push((oc, plane));
        }
        let work = (hw * taps) as u64 * c_out as u64;
        let outcomes: Vec<UnitOutcome> = if active > 1 && work >= PAR_MIN_TAP_SLOTS {
            std::thread::scope(|scope| {
                let plan = &plan;
                let handles: Vec<_> = self
                    .units
                    .iter_mut()
                    .take(active)
                    .zip(lanes)
                    .map(|(unit, lane)| {
                        scope.spawn(move || Self::run_conv_unit(unit, plan, lane))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("sim unit thread panicked"))
                    .collect()
            })
        } else {
            self.units
                .iter_mut()
                .take(active)
                .zip(lanes)
                .map(|(unit, lane)| Self::run_conv_unit(unit, &plan, lane))
                .collect()
        };

        let wall = outcomes.iter().map(|o| o.cycles).max().unwrap_or(0);
        let skip_reads: u64 = outcomes.iter().map(|o| o.skip_reads).sum();
        if skip_reads > 0 {
            self.mem.read_skip(skip_reads);
        }

        // Memory system: IFM streamed per iteration group, weights once.
        let iterations = (c_out as u64).div_ceil(active as u64);
        let ifm = x.shape().iter().product::<usize>() as u64;
        let wsize = (c_out * c_in * k * k) as u64;
        self.mem.stream_input(ifm, iterations, 0);
        self.mem.stream_weights(wsize, 0);
        self.mem.write_output(out_shape.elems(), false);

        Ok((out, wall, Self::conv_label(node, false)))
    }

    /// One unit's share of a conv layer: its round-robin output channels,
    /// group by group, through [`SfMmcnUnit::run_group_flat`]. Runs on a
    /// scoped thread for large layers; the unit owns its PEs/stats and
    /// `lane` holds disjoint output planes, so no synchronization exists
    /// until the layer barrier (thread join).
    fn run_conv_unit(
        unit: &mut SfMmcnUnit,
        plan: &ConvPlan<'_>,
        lane: Vec<(usize, &mut [f32])>,
    ) -> UnitOutcome {
        let taps = plan.taps;
        let mut outputs: Vec<Fixed> = Vec::with_capacity(WORKERS);
        let mut cycles = 0u64;
        let mut skip_reads = 0u64;
        for (oc, plane) in lane {
            let fw = &plan.qw[oc * taps..(oc + 1) * taps];
            let mut time_proj = 0.0f32;
            let mut dense_done = plan.emb.is_none();
            for &(p, gw, reused) in plan.groups {
                let server = match plan.residual {
                    Residual::None => match (plan.emb, dense_done) {
                        (Some(emb), false) => FlatServer::Dense {
                            x: emb,
                            w: &plan.qw_time.unwrap()
                                [oc * emb.len()..(oc + 1) * emb.len()],
                            zeros: plan.emb_zeros,
                        },
                        _ => FlatServer::Idle,
                    },
                    Residual::Identity { .. } => {
                        skip_reads += gw as u64;
                        FlatServer::Identity(
                            &plan.sq[oc * plan.hw + p..oc * plan.hw + p + gw],
                        )
                    }
                    Residual::Conv { .. } => {
                        skip_reads += (gw * plan.c_skip) as u64;
                        FlatServer::Conv {
                            windows: &plan.rwin[p * plan.c_skip..(p + gw) * plan.c_skip],
                            rtaps: plan.c_skip,
                            weights: &plan.qw_res.unwrap()
                                [oc * plan.c_skip..(oc + 1) * plan.c_skip],
                            zeros: &plan.rzeros[p..p + gw],
                        }
                    }
                };
                let (cyc, dense_out) = unit.run_group_flat(
                    &plan.windows[p * taps..(p + gw) * taps],
                    gw,
                    taps,
                    &plan.win_zeros[p..p + gw],
                    fw,
                    server,
                    reused,
                    &mut outputs,
                );
                cycles += cyc;
                if let Some(d) = dense_out {
                    time_proj = d.to_f32();
                    dense_done = true;
                }
                for (i, o) in outputs.iter().enumerate() {
                    let v = o.to_f32() + plan.bias[oc] + time_proj;
                    plane[p + i] = Self::apply_act(v, plan.act);
                }
            }
        }
        UnitOutcome { cycles, skip_reads }
    }

    /// Reference-path conv execution — the seed implementation, preserved
    /// verbatim (per-oc window extraction, `Vec<Vec<Fixed>>` groups,
    /// cycle-level unit driver). The small-input split path lives here
    /// and is shared by both paths.
    fn run_conv_ref(
        &mut self,
        node: &Node,
        x: &Tensor,
        nw: &NodeWeights,
        skip: Option<&Tensor>,
        time_emb: Option<&[f32]>,
    ) -> Result<(Tensor, u64, String)> {
        let (c_in, c_out, k, stride, pad, act, residual, time_dense) = match &node.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                stride,
                pad,
                act,
                residual,
                time_dense,
            } => (
                *c_in, *c_out, *k, *stride, *pad, *act, *residual, *time_dense,
            ),
            _ => unreachable!(),
        };
        if time_dense.is_some() && !matches!(residual, Residual::None) {
            bail!("a conv cannot host both time-dense and a residual on PE_9");
        }
        let out_shape = node.out_shape;
        let mut out = Tensor::zeros(&[out_shape.c, out_shape.h, out_shape.w]);

        let active = self.active_units(c_in);
        let taps_len = c_in * k * k;

        // Time-embedding projections (PE_9's dense results), one per oc.
        let t_emb_fx: Option<Vec<Fixed>> = match (time_dense, time_emb) {
            (Some(td), Some(e)) => {
                if e.len() != td {
                    bail!("time embedding len {} != layer's {}", e.len(), td);
                }
                Some(e.iter().map(|&v| Fixed::from_f32(v)).collect())
            }
            (Some(_), None) => bail!("graph needs a time embedding, none supplied"),
            _ => None,
        };
        let mut time_proj: Vec<f32> = vec![0.0; c_out];

        // Memory accounting at layer level.
        let iterations = (c_out as u64).div_ceil(active as u64);
        let ifm = x.shape().iter().product::<usize>() as u64;
        let wsize = (c_out * c_in * k * k) as u64;

        let mut per_unit_cycles = vec![0u64; self.cfg.units];

        // ---- small-input split path (Figs 11-12) -------------------------
        // Tiny maps (<= 4 outputs per channel) waste half the PE array in
        // normal mode; the control unit instead splits the array into two
        // 4-lane halves and runs two output channels per window.
        let hw_total = out_shape.h * out_shape.w;
        let xq = Self::quantize_map(x);
        let (h_in_d, w_in_d) = (x.shape()[1], x.shape()[2]);
        if hw_total <= 4 && c_out >= 2 {
            // Per-oc payloads (owned so the split groups can borrow them).
            struct OcData {
                pos: Vec<(usize, usize)>,
                windows: Vec<Vec<Fixed>>,
                fw: Vec<Fixed>,
                skip_vals: Option<Vec<Fixed>>,
                rwindows: Option<Vec<Vec<Fixed>>>,
                rw: Option<Vec<Fixed>>,
                dense: Option<(Vec<Fixed>, Vec<Fixed>)>,
            }
            let mut build = |oc: usize| -> Result<OcData> {
                let pos: Vec<(usize, usize)> = (0..hw_total)
                    .map(|q| (q / out_shape.w, q % out_shape.w))
                    .collect();
                let windows: Vec<Vec<Fixed>> = pos
                    .iter()
                    .map(|&(oy, ox)| {
                        let mut buf = Vec::with_capacity(taps_len);
                        Self::fill_window(
                            &xq, h_in_d, w_in_d, oy, ox, k, stride, pad, c_in, &mut buf,
                        );
                        buf
                    })
                    .collect();
                let fw = Self::filter(&nw.w, oc, c_in, k);
                let mut skip_vals = None;
                let mut rwindows = None;
                let mut rw = None;
                match residual {
                    Residual::None => {}
                    Residual::Identity { .. } => {
                        let s = skip.context("identity residual needs skip")?;
                        skip_vals = Some(
                            pos.iter()
                                .map(|&(oy, ox)| Fixed::from_f32(s.get(&[oc, oy, ox])))
                                .collect::<Vec<_>>(),
                        );
                        self.mem.read_skip(hw_total as u64);
                    }
                    Residual::Conv {
                        stride: rstride, ..
                    } => {
                        let s = skip.context("conv residual needs skip")?;
                        let c_skip = s.shape()[0];
                        rwindows = Some(
                            pos.iter()
                                .map(|&(oy, ox)| {
                                    (0..c_skip)
                                        .map(|c| {
                                            Fixed::from_f32(
                                                s.get(&[c, oy * rstride, ox * rstride]),
                                            )
                                        })
                                        .collect::<Vec<_>>()
                                })
                                .collect::<Vec<_>>(),
                        );
                        rw = Some(
                            (0..c_skip)
                                .map(|c| {
                                    Fixed::from_f32(
                                        nw.w_res.as_ref().unwrap().get(&[oc, c]),
                                    )
                                })
                                .collect::<Vec<_>>(),
                        );
                        self.mem.read_skip((hw_total * c_skip) as u64);
                    }
                }
                let dense = t_emb_fx.as_ref().map(|emb| {
                    let dwt: Vec<Fixed> = (0..emb.len())
                        .map(|j| Fixed::from_f32(nw.w_time.as_ref().unwrap().get(&[oc, j])))
                        .collect();
                    (emb.clone(), dwt)
                });
                Ok(OcData {
                    pos,
                    windows,
                    fw,
                    skip_vals,
                    rwindows,
                    rw,
                    dense,
                })
            };
            fn server_of(d: &OcData) -> ServerTask<'_> {
                if let Some(sv) = &d.skip_vals {
                    ServerTask::ServeIdentity(sv)
                } else if let Some(rws) = &d.rwindows {
                    ServerTask::ServeConv {
                        windows: rws,
                        weights: d.rw.as_ref().unwrap(),
                    }
                } else if let Some((dx, dwt)) = &d.dense {
                    ServerTask::Dense { x: dx, w: dwt }
                } else {
                    ServerTask::Idle
                }
            }

            let total_inputs = (hw_total * taps_len) as u64;
            let distinct_a = conv_group_distinct(
                c_in,
                k,
                stride,
                self.cfg.data_reuse,
                0,
                hw_total,
                out_shape.w,
            )
            .min(total_inputs);

            let mut oc = 0usize;
            while oc + 1 < c_out {
                let unit_idx = (oc / 2) % active;
                let da = build(oc)?;
                let db = build(oc + 1)?;
                let ga = ConvGroup {
                    windows: &da.windows,
                    weights: &da.fw,
                    server: server_of(&da),
                    reused_inputs: total_inputs - distinct_a,
                };
                // half B windows the same input map: full register reuse
                let gb = ConvGroup {
                    windows: &db.windows,
                    weights: &db.fw,
                    server: server_of(&db),
                    reused_inputs: if self.cfg.data_reuse { total_inputs } else { 0 },
                };
                let (ra, rb) = self.units[unit_idx].run_split_group(&ga, &gb);
                per_unit_cycles[unit_idx] += ra.cycles;
                for (half_oc, d, r) in [(oc, &da, &ra), (oc + 1, &db, &rb)] {
                    if let Some(dout) = r.dense_out {
                        time_proj[half_oc] = dout.to_f32();
                    }
                    for (i, o) in r.outputs.iter().enumerate() {
                        let (oy, ox) = d.pos[i];
                        let v = o.to_f32() + nw.bias[half_oc] + time_proj[half_oc];
                        out.set(&[half_oc, oy, ox], Self::apply_act(v, act));
                    }
                }
                oc += 2;
            }
            if oc < c_out {
                // odd tail channel: plain group
                let unit_idx = (oc / 2) % active;
                let d = build(oc)?;
                let g = ConvGroup {
                    windows: &d.windows,
                    weights: &d.fw,
                    server: server_of(&d),
                    reused_inputs: total_inputs - distinct_a,
                };
                let r = self.units[unit_idx].run_group(&g);
                per_unit_cycles[unit_idx] += r.cycles;
                if let Some(dout) = r.dense_out {
                    time_proj[oc] = dout.to_f32();
                }
                for (i, o) in r.outputs.iter().enumerate() {
                    let (oy, ox) = d.pos[i];
                    let v = o.to_f32() + nw.bias[oc] + time_proj[oc];
                    out.set(&[oc, oy, ox], Self::apply_act(v, act));
                }
            }

            self.mem.stream_input(ifm, iterations, 0);
            self.mem.stream_weights(wsize, 0);
            self.mem.write_output(out_shape.elems(), false);
            let wall = *per_unit_cycles.iter().max().unwrap_or(&0);
            return Ok((out, wall, Self::conv_label(node, true)));
        }

        // Scratch buffers reused across every group of the layer.
        let mut windows: Vec<Vec<Fixed>> =
            (0..WORKERS).map(|_| Vec::with_capacity(taps_len)).collect();
        let mut pos: Vec<(usize, usize)> = Vec::with_capacity(WORKERS);

        for oc in 0..c_out {
            let unit_idx = oc % active;
            let fw = Self::filter(&nw.w, oc, c_in, k);
            let rw: Option<Vec<Fixed>> = nw.w_res.as_ref().map(|wr| {
                let c_skip = wr.shape()[1];
                (0..c_skip)
                    .map(|c| Fixed::from_f32(wr.get(&[oc, c])))
                    .collect()
            });
            let mut dense_done = t_emb_fx.is_none();

            // Output positions are flattened row-major and grouped 8 at a
            // time; a group may wrap across rows (the paper's dataflow has
            // no per-row bubbles — series layers sustain 8/9 utilization).
            let hw = out_shape.h * out_shape.w;
            let mut p = 0usize;
            while p < hw {
                {
                    let gw = WORKERS.min(hw - p);
                    pos.clear();
                    pos.extend((p..p + gw).map(|q| (q / out_shape.w, q % out_shape.w)));
                    for (i, &(oy, ox)) in pos.iter().enumerate() {
                        Self::fill_window(
                            &xq,
                            h_in_d,
                            w_in_d,
                            oy,
                            ox,
                            k,
                            stride,
                            pad,
                            c_in,
                            &mut windows[i],
                        );
                    }
                    let windows = &windows[..gw];
                    let total_inputs = (gw * taps_len) as u64;
                    let reused = total_inputs
                        - conv_group_distinct(
                            c_in,
                            k,
                            stride,
                            self.cfg.data_reuse,
                            p,
                            gw,
                            out_shape.w,
                        )
                        .min(total_inputs);

                    // Build the server task.
                    let skip_vals: Vec<Fixed>;
                    let rwindows: Vec<Vec<Fixed>>;
                    let dx: Vec<Fixed>;
                    let dw: Vec<Fixed>;
                    let server = match residual {
                        Residual::None => {
                            if let (Some(emb), false) = (&t_emb_fx, dense_done) {
                                dx = emb.clone();
                                dw = (0..emb.len())
                                    .map(|j| {
                                        Fixed::from_f32(
                                            nw.w_time.as_ref().unwrap().get(&[oc, j]),
                                        )
                                    })
                                    .collect();
                                ServerTask::Dense { x: &dx, w: &dw }
                            } else {
                                ServerTask::Idle
                            }
                        }
                        Residual::Identity { .. } => {
                            let s = skip.context("identity residual needs skip")?;
                            skip_vals = pos
                                .iter()
                                .map(|&(oy, ox)| Fixed::from_f32(s.get(&[oc, oy, ox])))
                                .collect();
                            self.mem.read_skip(gw as u64);
                            ServerTask::ServeIdentity(&skip_vals)
                        }
                        Residual::Conv {
                            stride: rstride, ..
                        } => {
                            let s = skip.context("conv residual needs skip")?;
                            let c_skip = s.shape()[0];
                            rwindows = pos
                                .iter()
                                .map(|&(oy, ox)| {
                                    (0..c_skip)
                                        .map(|c| {
                                            Fixed::from_f32(s.get(&[
                                                c,
                                                oy * rstride,
                                                ox * rstride,
                                            ]))
                                        })
                                        .collect()
                                })
                                .collect();
                            self.mem.read_skip((gw * c_skip) as u64);
                            ServerTask::ServeConv {
                                windows: &rwindows,
                                weights: rw.as_ref().unwrap(),
                            }
                        }
                    };

                    let g = ConvGroup {
                        windows,
                        weights: &fw,
                        server,
                        reused_inputs: reused,
                    };
                    let r = self.units[unit_idx].run_group(&g);
                    per_unit_cycles[unit_idx] += r.cycles;

                    if let Some(d) = r.dense_out {
                        time_proj[oc] = d.to_f32();
                        dense_done = true;
                    }
                    for (i, o) in r.outputs.iter().enumerate() {
                        let (oy, ox) = pos[i];
                        let v = o.to_f32() + nw.bias[oc] + time_proj[oc];
                        out.set(&[oc, oy, ox], Self::apply_act(v, act));
                    }
                    p += gw;
                }
            }
        }

        // Memory system: IFM streamed per iteration group, weights once.
        let core_reads: u64 = 0; // unit stats already carry buffer reads
        self.mem.stream_input(ifm, iterations, core_reads);
        self.mem.stream_weights(wsize, 0);
        let ofm = out_shape.elems();
        self.mem.write_output(ofm, false);

        let wall = *per_unit_cycles.iter().max().unwrap_or(&0);
        // Units that finished early idle until the slowest one is done; the
        // energy model prices that via (total_pes*cycles - active) idling.
        Ok((out, wall, Self::conv_label(node, false)))
    }

    fn run_maxpool(
        &mut self,
        node: &Node,
        x: &Tensor,
        k: usize,
        stride: usize,
    ) -> (Tensor, u64, String) {
        let s = node.out_shape;
        let mut out = Tensor::zeros(&[s.c, s.h, s.w]);
        for c in 0..s.c {
            for oy in 0..s.h {
                for ox in 0..s.w {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..k {
                        for kx in 0..k {
                            m = m.max(x.get(&[c, oy * stride + ky, ox * stride + kx]));
                        }
                    }
                    // pooling unit works on the quantized stream
                    out.set(&[c, oy, ox], Fixed::from_f32(m).to_f32());
                }
            }
        }
        let outs = s.elems();
        let reads = outs * (k * k) as u64;
        self.mem.stats.input_buf_reads += reads;
        self.mem.write_output(outs, false);
        // Pooling unit throughput: one output per lane per cycle.
        let lanes = (self.cfg.units * WORKERS) as u64;
        let wall = outs.div_ceil(lanes);
        (out, wall, format!("maxpool{k}/{stride}"))
    }

    fn run_gap(&mut self, node: &Node, x: &Tensor) -> (Tensor, u64, String) {
        let c = node.in_shape.c;
        let hw = (node.in_shape.h * node.in_shape.w) as f32;
        let mut out = Tensor::zeros(&[c, 1, 1]);
        for ch in 0..c {
            let mut acc = 0.0;
            for y in 0..node.in_shape.h {
                for xq in 0..node.in_shape.w {
                    acc += x.get(&[ch, y, xq]);
                }
            }
            out.set(&[ch, 0, 0], Fixed::from_f32(acc / hw).to_f32());
        }
        let ins = node.in_shape.elems();
        self.mem.stats.input_buf_reads += ins;
        self.mem.write_output(c as u64, false);
        let lanes = (self.cfg.units * WORKERS) as u64;
        (out, ins.div_ceil(lanes), "gap".into())
    }

    /// §Perf hot path: dense layers run as 8-neuron groups over the flat
    /// quantized weight rows (the worker windows), threaded per unit.
    fn run_dense(
        &mut self,
        node: &Node,
        x: &Tensor,
        nw: &NodeWeights,
        nq: &NodeQuant,
        act: Act,
    ) -> Result<(Tensor, u64, String)> {
        let in_f = x.len();
        let out_f = node.out_shape.c;
        let xq: Vec<Fixed> = x.data().iter().map(|&v| Fixed::from_f32(v)).collect();
        let mut out = Tensor::zeros(&[out_f, 1, 1]);
        let active = self.cfg.units;

        let plan = DensePlan {
            in_f,
            act,
            qw: &nq.w,
            w_zeros: &nq.w_zeros,
            xq: &xq,
            bias: &nw.bias,
        };
        let mut lanes: Vec<Vec<(usize, &mut [f32])>> =
            (0..active).map(|_| Vec::new()).collect();
        for (gidx, chunk) in out.data_mut().chunks_mut(WORKERS).enumerate() {
            lanes[gidx % active].push((gidx, chunk));
        }
        let work = (in_f * out_f) as u64;
        let outcomes: Vec<UnitOutcome> = if active > 1 && work >= PAR_MIN_TAP_SLOTS {
            std::thread::scope(|scope| {
                let plan = &plan;
                let handles: Vec<_> = self
                    .units
                    .iter_mut()
                    .take(active)
                    .zip(lanes)
                    .map(|(unit, lane)| {
                        scope.spawn(move || Self::run_dense_unit(unit, plan, lane))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("sim unit thread panicked"))
                    .collect()
            })
        } else {
            self.units
                .iter_mut()
                .take(active)
                .zip(lanes)
                .map(|(unit, lane)| Self::run_dense_unit(unit, &plan, lane))
                .collect()
        };
        let wall = outcomes.iter().map(|o| o.cycles).max().unwrap_or(0);

        self.mem.stream_input(in_f as u64, 1, 0);
        self.mem.stream_weights((in_f * out_f) as u64, 0);
        self.mem.write_output(out_f as u64, false);
        Ok((out, wall, format!("dense {in_f}->{out_f}")))
    }

    /// One unit's share of a dense layer (its round-robin neuron groups).
    fn run_dense_unit(
        unit: &mut SfMmcnUnit,
        plan: &DensePlan<'_>,
        lane: Vec<(usize, &mut [f32])>,
    ) -> UnitOutcome {
        let in_f = plan.in_f;
        let mut outputs: Vec<Fixed> = Vec::with_capacity(WORKERS);
        let mut cycles = 0u64;
        for (gidx, chunk) in lane {
            let n0 = gidx * WORKERS;
            let gw = chunk.len();
            // Each "window" is a neuron's weight row; the input vector is
            // broadcast as the shared filter (MAC is commutative, counts
            // identical, reuse = inputs broadcast) — see run_dense_ref.
            let reused = (gw.saturating_sub(1) * in_f) as u64;
            let (cyc, _) = unit.run_group_flat(
                &plan.qw[n0 * in_f..(n0 + gw) * in_f],
                gw,
                in_f,
                &plan.w_zeros[n0..n0 + gw],
                plan.xq,
                FlatServer::Idle,
                reused,
                &mut outputs,
            );
            cycles += cyc;
            for (i, o) in outputs.iter().enumerate() {
                let v = o.to_f32() + plan.bias[n0 + i];
                chunk[i] = Self::apply_act(v, plan.act);
            }
        }
        UnitOutcome {
            cycles,
            skip_reads: 0,
        }
    }

    /// Reference-path dense execution (seed implementation).
    fn run_dense_ref(
        &mut self,
        node: &Node,
        x: &Tensor,
        nw: &NodeWeights,
        act: Act,
    ) -> Result<(Tensor, u64, String)> {
        let in_f = x.len();
        let out_f = node.out_shape.c;
        let xq: Vec<Fixed> = x.data().iter().map(|&v| Fixed::from_f32(v)).collect();
        let mut out = Tensor::zeros(&[out_f, 1, 1]);
        let active = self.cfg.units;
        let mut per_unit_cycles = vec![0u64; self.cfg.units];

        // Dense runs as conv-of-in_f-taps groups: 8 neurons per unit pass.
        let mut neuron = 0usize;
        while neuron < out_f {
            let unit_idx = (neuron / WORKERS) % active;
            let gw = WORKERS.min(out_f - neuron);
            // Each "window" is the shared input vector; weights differ per
            // neuron, so in hardware the input is broadcast and weights
            // stream per PE. Model as gw single-window groups on one unit
            // is wrong (cycles); instead run one group where windows are
            // the per-neuron WEIGHT rows and the shared filter is x — MAC
            // is commutative, counts identical, reuse = inputs broadcast.
            let windows: Vec<Vec<Fixed>> = (neuron..neuron + gw)
                .map(|n| {
                    (0..in_f)
                        .map(|j| Fixed::from_f32(nw.w.get(&[n, j])))
                        .collect()
                })
                .collect();
            let reused = (gw.saturating_sub(1) * in_f) as u64; // x broadcast
            let g = ConvGroup {
                windows: &windows,
                weights: &xq,
                server: ServerTask::Idle,
                reused_inputs: reused,
            };
            let r = self.units[unit_idx].run_group(&g);
            per_unit_cycles[unit_idx] += r.cycles;
            for (i, o) in r.outputs.iter().enumerate() {
                let v = o.to_f32() + nw.bias[neuron + i];
                out.set(&[neuron + i, 0, 0], Self::apply_act(v, act));
            }
            neuron += gw;
        }

        self.mem.stream_input(in_f as u64, 1, 0);
        self.mem.stream_weights((in_f * out_f) as u64, 0);
        self.mem.write_output(out_f as u64, false);
        let wall = *per_unit_cycles.iter().max().unwrap();
        Ok((out, wall, format!("dense {in_f}->{out_f}")))
    }

    fn run_upsample(&mut self, node: &Node, x: &Tensor) -> (Tensor, u64, String) {
        let s = node.out_shape;
        let out = Tensor::from_fn(&[s.c, s.h, s.w], |idx| {
            x.get(&[idx[0], idx[1] / 2, idx[2] / 2])
        });
        let elems = s.elems();
        self.mem.stats.input_buf_reads += node.in_shape.elems();
        self.mem.write_output(elems, false);
        let lanes = (self.cfg.units * WORKERS) as u64;
        (out, elems.div_ceil(lanes), "upsample2x".into())
    }

    fn run_concat(
        &mut self,
        node: &Node,
        x: &Tensor,
        skip: &Tensor,
    ) -> Result<(Tensor, u64, String)> {
        let s = node.out_shape;
        let c_x = x.shape()[0];
        let out = Tensor::from_fn(&[s.c, s.h, s.w], |idx| {
            if idx[0] < c_x {
                x.get(idx)
            } else {
                skip.get(&[idx[0] - c_x, idx[1], idx[2]])
            }
        });
        let elems = s.elems();
        self.mem.stats.input_buf_reads += elems;
        self.mem.write_output(elems, false);
        let lanes = (self.cfg.units * WORKERS) as u64;
        Ok((out, elems.div_ceil(lanes), "concat".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::graph::{GraphBuilder, Layer as L, TensorShape};

    /// Float reference conv for numerics checks (same padding semantics).
    fn ref_conv(
        x: &Tensor,
        w: &Tensor,
        bias: &[f32],
        k: usize,
        stride: usize,
        pad: usize,
    ) -> Tensor {
        let (c_in, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let c_out = w.shape()[0];
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (wd + 2 * pad - k) / stride + 1;
        Tensor::from_fn(&[c_out, oh, ow], |idx| {
            let (oc, oy, ox) = (idx[0], idx[1], idx[2]);
            let mut acc = bias[oc];
            for c in 0..c_in {
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < wd {
                            acc += x.get(&[c, iy as usize, ix as usize])
                                * w.get(&[oc, c, ky, kx]);
                        }
                    }
                }
            }
            acc
        })
    }

    fn simple_conv_graph(c_in: usize, c_out: usize, hw: usize) -> ModelGraph {
        let mut b = GraphBuilder::new("t", TensorShape::new(c_in, hw, hw));
        b.add(L::Conv {
            c_in,
            c_out,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        b.build()
    }

    #[test]
    fn conv_numerics_match_float_reference() {
        let g = simple_conv_graph(3, 8, 12);
        let ws = WeightStore::random(&g, 7);
        let mut rng = Rng::new(3);
        let x = Tensor::from_fn(&[3, 12, 12], |_| rng.normal() * 0.5);
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        let run = acc.run_graph(&g, &x, &ws, None).unwrap();
        let nw = ws.per_node[0].as_ref().unwrap();
        let reference = ref_conv(&x, &nw.w, &nw.bias, 3, 1, 1);
        let diff = run.output.max_abs_diff(&reference).unwrap();
        // Q8.8 quantization of inputs+weights+outputs over 27 taps
        assert!(diff < 0.08, "max diff {diff}");
    }

    #[test]
    fn residual_identity_matches_reference_add() {
        let mut b = GraphBuilder::new("t", TensorShape::new(4, 8, 8));
        b.add(L::Conv {
            c_in: 4,
            c_out: 4,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        b.add(L::Conv {
            c_in: 4,
            c_out: 4,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::Identity { from: 0 },
            time_dense: None,
        })
        .unwrap();
        let g = b.build();
        let ws = WeightStore::random(&g, 11);
        let mut rng = Rng::new(5);
        let x = Tensor::from_fn(&[4, 8, 8], |_| rng.normal() * 0.3);
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        let run = acc.run_graph(&g, &x, &ws, None).unwrap();

        let n0 = ws.per_node[0].as_ref().unwrap();
        let n1 = ws.per_node[1].as_ref().unwrap();
        let y0 = ref_conv(&x, &n0.w, &n0.bias, 3, 1, 1);
        let y1 = ref_conv(&y0, &n1.w, &n1.bias, 3, 1, 1).add(&y0).unwrap();
        let diff = run.output.max_abs_diff(&y1).unwrap();
        assert!(diff < 0.15, "max diff {diff}");
    }

    #[test]
    fn residual_adds_no_cycles() {
        // same shapes, with and without residual: wall cycles must match
        let mk = |residual| {
            let mut b = GraphBuilder::new("t", TensorShape::new(4, 8, 8));
            b.add(L::Conv {
                c_in: 4,
                c_out: 4,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual: Residual::None,
                time_dense: None,
            })
            .unwrap();
            b.add(L::Conv {
                c_in: 4,
                c_out: 4,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual,
                time_dense: None,
            })
            .unwrap();
            b.build()
        };
        let g_plain = mk(Residual::None);
        let g_res = mk(Residual::Identity { from: 0 });
        let x = Tensor::full(&[4, 8, 8], 0.1);
        let ws_p = WeightStore::random(&g_plain, 1);
        let ws_r = WeightStore::random(&g_res, 1);
        let mut a1 = Accelerator::new(AcceleratorConfig::default());
        let mut a2 = Accelerator::new(AcceleratorConfig::default());
        let r1 = a1.run_graph(&g_plain, &x, &ws_p, None).unwrap();
        let r2 = a2.run_graph(&g_res, &x, &ws_r, None).unwrap();
        assert_eq!(
            r1.total_cycles(),
            r2.total_cycles(),
            "SF must absorb the residual at zero cycle cost"
        );
        // ...and the residual run has 100% utilization on the fused layer
        assert!(r2.layers[1].u_pe > r1.layers[1].u_pe);
    }

    #[test]
    fn first_layer_unit_throttling() {
        // c_in = 3 -> only 6 of 8 units active (paper Fig 21 explanation)
        let acc = Accelerator::new(AcceleratorConfig::default());
        assert_eq!(acc.active_units(3), 6);
        assert_eq!(acc.active_units(64), 8);
        assert_eq!(acc.active_units(1), 2);
    }

    #[test]
    fn total_pes_counts_workers_and_server() {
        // 8 units x (8 workers + PE_9) = 72 PEs — the paper's Table-I
        // organisation; sweeps scale linearly.
        assert_eq!(AcceleratorConfig::default().total_pes(), 72);
        assert_eq!(AcceleratorConfig::with_units(1).total_pes(), 9);
        assert_eq!(AcceleratorConfig::with_units(16).total_pes(), 144);
    }

    #[test]
    fn default_buffer_capacities_are_elements_not_bytes() {
        // 64 Ki 16-bit elements = 128 KiB; 16 Ki elements = 32 KiB.
        let cfg = AcceleratorConfig::default();
        assert_eq!(cfg.input_buf_elems * 2, 128 * 1024);
        assert_eq!(cfg.weight_buf_elems * 2, 32 * 1024);
    }

    #[test]
    fn maxpool_numerics() {
        let mut b = GraphBuilder::new("t", TensorShape::new(1, 4, 4));
        b.add(L::MaxPool { k: 2, stride: 2 }).unwrap();
        let g = b.build();
        let ws = WeightStore::random(&g, 0);
        let x = Tensor::new(
            &[1, 4, 4],
            vec![
                1.0, 2.0, 3.0, 4.0, //
                5.0, 6.0, 7.0, 8.0, //
                9.0, 10.0, 11.0, 12.0, //
                13.0, 14.0, 15.0, 16.0,
            ],
        )
        .unwrap();
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        let run = acc.run_graph(&g, &x, &ws, None).unwrap();
        assert_eq!(run.output.data(), &[6.0, 8.0, 14.0, 16.0]);
    }

    #[test]
    fn dense_numerics() {
        let mut b = GraphBuilder::new("t", TensorShape::new(2, 2, 2));
        b.add(L::Dense {
            in_f: 8,
            out_f: 4,
            act: Act::None,
        })
        .unwrap();
        let g = b.build();
        let ws = WeightStore::random(&g, 3);
        let mut rng = Rng::new(8);
        let x = Tensor::from_fn(&[2, 2, 2], |_| rng.normal() * 0.5);
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        let run = acc.run_graph(&g, &x, &ws, None).unwrap();
        let nw = ws.per_node[0].as_ref().unwrap();
        for n in 0..4 {
            let mut want = nw.bias[n];
            for j in 0..8 {
                want += nw.w.get(&[n, j]) * x.data()[j];
            }
            let got = run.output.get(&[n, 0, 0]);
            assert!((got - want).abs() < 0.05, "neuron {n}: {got} vs {want}");
        }
    }

    #[test]
    fn unet_block_time_dense_applies_bias() {
        let mut b = GraphBuilder::new("t", TensorShape::new(2, 4, 4));
        b.add(L::Conv {
            c_in: 2,
            c_out: 2,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::None,
            time_dense: Some(4),
        })
        .unwrap();
        let g = b.build();
        let ws = WeightStore::random(&g, 5);
        let x = Tensor::full(&[2, 4, 4], 0.2);
        let emb = vec![0.5f32, -0.25, 1.0, 0.125];
        let mut a1 = Accelerator::new(AcceleratorConfig::default());
        let with_t = a1.run_graph(&g, &x, &ws, Some(&emb)).unwrap();
        // missing embedding must error
        let mut a2 = Accelerator::new(AcceleratorConfig::default());
        assert!(a2.run_graph(&g, &x, &ws, None).is_err());
        // the time projection shifts channel outputs by a per-channel bias
        let nw = ws.per_node[0].as_ref().unwrap();
        let wt = nw.w_time.as_ref().unwrap();
        for oc in 0..2 {
            let proj: f32 = (0..4).map(|j| emb[j] * wt.get(&[oc, j])).sum();
            let base = ref_conv(&x, &nw.w, &nw.bias, 3, 1, 1);
            let want = base.get(&[oc, 1, 1]) + proj;
            let got = with_t.output.get(&[oc, 1, 1]);
            assert!((got - want).abs() < 0.1, "{got} vs {want}");
        }
    }

    #[test]
    fn full_small_graph_runs() {
        let g = crate::models::unet(crate::models::UnetConfig {
            img: 8,
            base_c: 4,
            levels: 1,
            time_dim: 8,
            img_channels: 1,
        });
        let ws = WeightStore::random(&g, 2);
        let x = Tensor::full(&[1, 8, 8], 0.5);
        let emb = vec![0.1f32; 8];
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        let run = acc.run_graph(&g, &x, &ws, Some(&emb)).unwrap();
        assert_eq!(run.output.shape(), &[1, 8, 8]);
        assert!(run.total_cycles() > 0);
        assert!(run.totals.pe.macs > 0);
    }

    #[test]
    fn fast_path_matches_reference_path_smoke() {
        // The full bit-exactness suite lives in tests/sim_golden.rs; this
        // is the in-crate smoke version on a residual pair.
        let mut b = GraphBuilder::new("t", TensorShape::new(3, 10, 10));
        b.add(L::Conv {
            c_in: 3,
            c_out: 5,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::Relu,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        b.add(L::Conv {
            c_in: 5,
            c_out: 5,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::None,
            residual: Residual::Identity { from: 0 },
            time_dense: None,
        })
        .unwrap();
        let g = b.build();
        let ws = WeightStore::random(&g, 9);
        let mut rng = Rng::new(4);
        let x = Tensor::from_fn(&[3, 10, 10], |_| rng.normal() * 0.5);
        let mut a_fast = Accelerator::new(AcceleratorConfig::default());
        let mut a_ref = Accelerator::new(AcceleratorConfig::default());
        let fast = a_fast.run_graph(&g, &x, &ws, None).unwrap();
        let reference = a_ref.run_graph_ref(&g, &x, &ws, None).unwrap();
        assert_eq!(fast.output.data(), reference.output.data(), "outputs");
        assert_eq!(fast.total_cycles(), reference.total_cycles(), "cycles");
        assert_eq!(fast.totals.pe, reference.totals.pe, "pe stats");
    }
}
