//! Cycle/event trace — the software analogue of the paper's waveform
//! figures (Fig 7 and Fig 19a). Mostly used by the quickstart example and
//! the dataflow-comparison bench to *show* where the SF cycles go.

use std::fmt::Write as _;

/// One traced event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub cycle: u64,
    pub lane: String,
    pub what: String,
}

/// An append-only trace with a bounded capacity (drops beyond the cap so
/// full-model runs can keep tracing enabled cheaply).
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Trace {
    pub fn new(cap: usize) -> Self {
        Self {
            events: Vec::new(),
            cap,
            dropped: 0,
        }
    }

    pub fn push(&mut self, cycle: u64, lane: &str, what: &str) {
        if self.events.len() < self.cap {
            self.events.push(TraceEvent {
                cycle,
                lane: lane.to_string(),
                what: what.to_string(),
            });
        } else {
            self.dropped += 1;
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Render an ASCII waveform: one row per lane, one column per cycle.
    /// Events are marked with the first character of `what`.
    pub fn render(&self, max_cycles: u64) -> String {
        let mut lanes: Vec<String> = Vec::new();
        for e in &self.events {
            if !lanes.contains(&e.lane) {
                lanes.push(e.lane.clone());
            }
        }
        let width = lanes.iter().map(|l| l.len()).max().unwrap_or(4).max(4);
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{:w$} | cycle 0..{}",
            "lane",
            max_cycles.min(120),
            w = width
        );
        for lane in &lanes {
            let mut row = vec![b'.'; max_cycles.min(120) as usize];
            for e in self.events.iter().filter(|e| &e.lane == lane) {
                if (e.cycle as usize) < row.len() {
                    row[e.cycle as usize] = e.what.bytes().next().unwrap_or(b'*');
                }
            }
            let _ = writeln!(
                out,
                "{:w$} | {}",
                lane,
                String::from_utf8_lossy(&row),
                w = width
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_until_cap_then_drops() {
        let mut t = Trace::new(3);
        for i in 0..5 {
            t.push(i, "pe1", "M");
        }
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn render_contains_lanes_and_marks() {
        let mut t = Trace::new(100);
        t.push(0, "PE_1", "M");
        t.push(1, "PE_1", "M");
        t.push(0, "PE_9", "S");
        let s = t.render(10);
        assert!(s.contains("PE_1"));
        assert!(s.contains("PE_9"));
        assert!(s.contains("MM"));
        assert!(s.contains('S'));
    }
}
