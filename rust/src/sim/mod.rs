//! Cycle-accurate simulator of the SF-MMCN micro-architecture.
//!
//! Hierarchy mirrors the paper's Figures 4, 5 and 18:
//!
//! * [`pe`] — one processing element: 16-bit MAC datapath with pipeline
//!   counter, zero-gate unit, residual adder and output mux (Fig 4).
//! * [`unit`] — one SF-MMCN unit: PE_1..PE_8 plus the PE_9 "server",
//!   server-flow mode control (Figs 5-6, 12), small-input split (Fig 11),
//!   and the 8 x 32-bit data-reuse registers (Fig 17).
//! * [`array`] — the implementation architecture: N units, TOP CTRL,
//!   input/weight buffers, pooling + activation units (Fig 18).
//! * [`memory`] — off-chip DRAM + on-chip buffer traffic accounting.
//! * [`energy`] — event-energy and area model calibrated to the paper's
//!   TSMC 40 nm synthesis results (Table I / Table III).
//! * [`trace`] — optional cycle/event trace (the software analogue of the
//!   paper's waveform figures 7 and 19a).
//!
//! The micro simulator computes *real fixed-point numerics* along with the
//! cycle/energy counts, so correctness and performance come from the same
//! code path. Full-network sweeps use the closed-form model in
//! [`crate::compiler::schedule`], which is property-tested against this
//! simulator on randomized small layers.

pub mod array;
pub mod energy;
pub mod memory;
pub mod pe;
pub mod trace;
pub mod unit;

pub use array::{Accelerator, AcceleratorConfig, LayerRun};
pub use energy::{EnergyModel, EventCounts, PpaReport, CAL_40NM};
pub use memory::MemoryStats;
pub use pe::{Pe, PeMode, PeStats};
pub use unit::{SfMmcnUnit, UnitMode, UnitStats};
