//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The interchange format is HLO *text* (not a serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The real PJRT executor needs the vendored `xla` crate and its
//! `xla_extension` shared library, which the default build environment
//! does not have — so it is gated behind the `pjrt` cargo feature and a
//! stub with the same API takes its place otherwise (see `stub.rs`). The
//! artifact store, [`TensorBuf`], the [`BufferPool`] arena backing the
//! zero-allocation serving hot path, and the [`NativeDenoise`] surrogate
//! (which lets the serving layer run offline, batched included) are
//! backend-independent and always available.

mod artifact;
#[cfg(feature = "pjrt")]
mod executor;
mod native;
mod pool;
#[cfg(not(feature = "pjrt"))]
mod stub;
mod tensor_buf;

pub use artifact::{ArtifactSpec, ArtifactStore};
#[cfg(feature = "pjrt")]
pub use executor::{Executor, PreparedInputs};
pub use native::{
    classify_row_scalar, step_kernel_scalar, BatchDispatch, NativeClassify, NativeDenoise,
};
#[cfg(feature = "simd")]
pub use native::{classify_row_simd, step_kernel_simd};
pub use pool::{BufferPool, PoolStats};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executor, PreparedInputs};
pub use tensor_buf::TensorBuf;
