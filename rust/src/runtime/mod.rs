//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The interchange format is HLO *text* (not a serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.

mod artifact;
mod executor;

pub use artifact::{ArtifactSpec, ArtifactStore};
pub use executor::{Executor, PreparedInputs, TensorBuf};
