//! Host-side tensors crossing the runtime boundary (backend-independent).

use anyhow::{bail, Result};

/// A host-side tensor: row-major `f32` data plus its shape.
///
/// This is the only tensor type that crosses the runtime boundary; the
/// simulator works in fixed-point (`crate::quant`) and converts at the edge.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorBuf {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Stack same-shaped tensors into one tensor with a new leading axis —
    /// how the batched serving path forms a `[B, ...]` device dispatch out
    /// of B per-request tensors.
    pub fn stack(parts: &[TensorBuf]) -> Result<TensorBuf> {
        let first = match parts.first() {
            Some(p) => p,
            None => bail!("stack of zero tensors"),
        };
        let mut data = Vec::with_capacity(first.len() * parts.len());
        for p in parts {
            if p.shape != first.shape {
                bail!(
                    "stack shape mismatch: {:?} vs {:?}",
                    p.shape,
                    first.shape
                );
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&first.shape);
        TensorBuf::new(shape, data)
    }

    /// Split along the leading axis into `shape[0]` tensors (inverse of
    /// [`TensorBuf::stack`]).
    pub fn unstack(&self) -> Result<Vec<TensorBuf>> {
        if self.shape.is_empty() {
            bail!("unstack of a rank-0 tensor");
        }
        let b = self.shape[0];
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let n: usize = inner.iter().product();
        let mut out = Vec::with_capacity(b);
        for i in 0..b {
            out.push(TensorBuf::new(
                inner.clone(),
                self.data[i * n..(i + 1) * n].to_vec(),
            )?);
        }
        Ok(out)
    }

    /// Copy rows `lo..lo+len` along the leading axis (row-major), keeping
    /// the trailing dims — how the batched path carves per-timestep-chunk
    /// views out of the whole-request embedding/coefficient tensors.
    pub fn slice_rows(&self, lo: usize, len: usize) -> Result<TensorBuf> {
        if self.shape.is_empty() {
            bail!("slice_rows of a rank-0 tensor");
        }
        let rows = self.shape[0];
        if lo + len > rows {
            bail!("slice_rows {lo}..{} out of {rows} rows", lo + len);
        }
        let n: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = len;
        TensorBuf::new(shape, self.data[lo * n..(lo + len) * n].to_vec())
    }

    // ---- zero-copy variants (ISSUE 4) ----------------------------------
    //
    // Each writes into caller-provided storage instead of allocating, so
    // a pooled serving lane can keep one set of slabs rotating through
    // the hot loop. Semantics (shapes, element order, error conditions)
    // mirror the allocating counterparts above bit for bit.

    /// [`TensorBuf::stack`] into `out`'s retained storage: `out` becomes
    /// the `[parts.len(), ...]` stack, reusing its backing slab (no
    /// allocation once the slab's capacity covers the batch).
    pub fn stack_into(parts: &[TensorBuf], out: &mut TensorBuf) -> Result<()> {
        let first = match parts.first() {
            Some(p) => p,
            None => bail!("stack of zero tensors"),
        };
        let n = first.len();
        for p in parts {
            if p.shape != first.shape {
                bail!(
                    "stack shape mismatch: {:?} vs {:?}",
                    p.shape,
                    first.shape
                );
            }
        }
        out.shape.clear();
        out.shape.push(parts.len());
        out.shape.extend_from_slice(&first.shape);
        // clear + extend writes each element exactly once, reusing the
        // slab's capacity (no dead zero-fill pass)
        out.data.clear();
        out.data.reserve(parts.len() * n);
        for p in parts {
            out.data.extend_from_slice(&p.data);
        }
        Ok(())
    }

    /// [`TensorBuf::unstack`] into preallocated per-row tensors: row `i`
    /// of the leading axis overwrites `outs[i]` (shape and data), reusing
    /// each output's backing slab.
    pub fn unstack_into(&self, outs: &mut [TensorBuf]) -> Result<()> {
        if self.shape.is_empty() {
            bail!("unstack of a rank-0 tensor");
        }
        let b = self.shape[0];
        if outs.len() != b {
            bail!("unstack_into: {} outputs for leading dim {b}", outs.len());
        }
        let inner = &self.shape[1..];
        let n: usize = inner.iter().product();
        for (i, o) in outs.iter_mut().enumerate() {
            o.shape.clear();
            o.shape.extend_from_slice(inner);
            o.data.clear();
            o.data.extend_from_slice(&self.data[i * n..(i + 1) * n]);
        }
        Ok(())
    }

    /// Copy one leading-axis row into a caller slab sized to exactly one
    /// row.
    pub fn copy_row_into(&self, row: usize, out: &mut [f32]) -> Result<()> {
        self.copy_rows_into(row, 1, out)
    }

    /// [`TensorBuf::slice_rows`] into a caller slab: copies rows
    /// `lo..lo+len` (keeping trailing dims) into `out`, which must be
    /// sized to exactly `len` rows.
    pub fn copy_rows_into(&self, lo: usize, len: usize, out: &mut [f32]) -> Result<()> {
        if self.shape.is_empty() {
            bail!("copy_rows_into of a rank-0 tensor");
        }
        let rows = self.shape[0];
        if lo + len > rows {
            bail!("copy_rows_into {lo}..{} out of {rows} rows", lo + len);
        }
        let n: usize = self.shape[1..].iter().product();
        if out.len() != len * n {
            bail!(
                "copy_rows_into: out slab holds {} elements, rows {lo}..{} need {}",
                out.len(),
                lo + len,
                len * n
            );
        }
        out.copy_from_slice(&self.data[lo * n..(lo + len) * n]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_buf_shape_checked() {
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 4]).is_ok());
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 5]).is_err());
    }

    #[test]
    fn tensor_buf_zeros() {
        let t = TensorBuf::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_is_rank_zero() {
        let t = TensorBuf::scalar(3.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.data, vec![3.5]);
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = TensorBuf::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = TensorBuf::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let s = TensorBuf::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape, vec![2, 2, 2]);
        assert_eq!(s.data[..4], a.data[..]);
        let parts = s.unstack().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn stack_rejects_mismatch_and_empty() {
        let a = TensorBuf::zeros(&[2]);
        let b = TensorBuf::zeros(&[3]);
        assert!(TensorBuf::stack(&[a, b]).is_err());
        assert!(TensorBuf::stack(&[]).is_err());
    }

    #[test]
    fn stack_into_matches_stack_and_reuses_storage() {
        let a = TensorBuf::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = TensorBuf::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let parts = [a, b];
        let alloc = TensorBuf::stack(&parts).unwrap();
        let mut out = TensorBuf::zeros(&[2, 2, 2]);
        let ptr = out.data.as_ptr();
        TensorBuf::stack_into(&parts, &mut out).unwrap();
        assert_eq!(out, alloc);
        assert_eq!(out.data.as_ptr(), ptr, "slab must be reused, not replaced");
        // shape/size mismatches rejected, empty rejected
        let c = TensorBuf::zeros(&[3]);
        assert!(TensorBuf::stack_into(&[parts[0].clone(), c], &mut out).is_err());
        assert!(TensorBuf::stack_into(&[], &mut out).is_err());
    }

    #[test]
    fn unstack_into_matches_unstack() {
        let s = TensorBuf::new(vec![2, 3], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let alloc = s.unstack().unwrap();
        let mut outs = vec![TensorBuf::zeros(&[3]), TensorBuf::zeros(&[3])];
        s.unstack_into(&mut outs).unwrap();
        assert_eq!(outs, alloc);
        // wrong output count rejected
        let mut short = vec![TensorBuf::zeros(&[3])];
        assert!(s.unstack_into(&mut short).is_err());
        assert!(TensorBuf::scalar(1.0).unstack_into(&mut outs).is_err());
    }

    #[test]
    fn copy_rows_into_matches_slice_rows() {
        let t = TensorBuf::new(vec![3, 2], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let sliced = t.slice_rows(1, 2).unwrap();
        let mut out = vec![0.0f32; 4];
        t.copy_rows_into(1, 2, &mut out).unwrap();
        assert_eq!(out, sliced.data);
        let mut row = vec![0.0f32; 2];
        t.copy_row_into(2, &mut row).unwrap();
        assert_eq!(row, vec![4.0, 5.0]);
        // out-of-range rows and wrong slab sizes rejected
        assert!(t.copy_rows_into(2, 2, &mut out).is_err());
        let mut bad = vec![0.0f32; 3];
        assert!(t.copy_rows_into(1, 2, &mut bad).is_err());
        assert!(TensorBuf::scalar(1.0).copy_row_into(0, &mut row).is_err());
    }

    #[test]
    fn slice_rows_copies_chunk() {
        let t = TensorBuf::new(vec![3, 2], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let s = t.slice_rows(1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 4.0, 5.0]);
        assert!(t.slice_rows(2, 2).is_err());
    }
}
