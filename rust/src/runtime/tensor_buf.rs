//! Host-side tensors crossing the runtime boundary (backend-independent).

use anyhow::{bail, Result};

/// A host-side tensor: row-major `f32` data plus its shape.
///
/// This is the only tensor type that crosses the runtime boundary; the
/// simulator works in fixed-point (`crate::quant`) and converts at the edge.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorBuf {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Stack same-shaped tensors into one tensor with a new leading axis —
    /// how the batched serving path forms a `[B, ...]` device dispatch out
    /// of B per-request tensors.
    pub fn stack(parts: &[TensorBuf]) -> Result<TensorBuf> {
        let first = match parts.first() {
            Some(p) => p,
            None => bail!("stack of zero tensors"),
        };
        let mut data = Vec::with_capacity(first.len() * parts.len());
        for p in parts {
            if p.shape != first.shape {
                bail!(
                    "stack shape mismatch: {:?} vs {:?}",
                    p.shape,
                    first.shape
                );
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&first.shape);
        TensorBuf::new(shape, data)
    }

    /// Split along the leading axis into `shape[0]` tensors (inverse of
    /// [`TensorBuf::stack`]).
    pub fn unstack(&self) -> Result<Vec<TensorBuf>> {
        if self.shape.is_empty() {
            bail!("unstack of a rank-0 tensor");
        }
        let b = self.shape[0];
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let n: usize = inner.iter().product();
        let mut out = Vec::with_capacity(b);
        for i in 0..b {
            out.push(TensorBuf::new(
                inner.clone(),
                self.data[i * n..(i + 1) * n].to_vec(),
            )?);
        }
        Ok(out)
    }

    /// Copy rows `lo..lo+len` along the leading axis (row-major), keeping
    /// the trailing dims — how the batched path carves per-timestep-chunk
    /// views out of the whole-request embedding/coefficient tensors.
    pub fn slice_rows(&self, lo: usize, len: usize) -> Result<TensorBuf> {
        if self.shape.is_empty() {
            bail!("slice_rows of a rank-0 tensor");
        }
        let rows = self.shape[0];
        if lo + len > rows {
            bail!("slice_rows {lo}..{} out of {rows} rows", lo + len);
        }
        let n: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = len;
        TensorBuf::new(shape, self.data[lo * n..(lo + len) * n].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_buf_shape_checked() {
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 4]).is_ok());
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 5]).is_err());
    }

    #[test]
    fn tensor_buf_zeros() {
        let t = TensorBuf::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_is_rank_zero() {
        let t = TensorBuf::scalar(3.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.data, vec![3.5]);
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = TensorBuf::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = TensorBuf::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let s = TensorBuf::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape, vec![2, 2, 2]);
        assert_eq!(s.data[..4], a.data[..]);
        let parts = s.unstack().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn stack_rejects_mismatch_and_empty() {
        let a = TensorBuf::zeros(&[2]);
        let b = TensorBuf::zeros(&[3]);
        assert!(TensorBuf::stack(&[a, b]).is_err());
        assert!(TensorBuf::stack(&[]).is_err());
    }

    #[test]
    fn slice_rows_copies_chunk() {
        let t = TensorBuf::new(vec![3, 2], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let s = t.slice_rows(1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 4.0, 5.0]);
        assert!(t.slice_rows(2, 2).is_err());
    }
}
