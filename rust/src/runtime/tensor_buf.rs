//! Host-side tensors crossing the runtime boundary (backend-independent).

use anyhow::{bail, Result};

/// A host-side tensor: row-major `f32` data plus its shape.
///
/// This is the only tensor type that crosses the runtime boundary; the
/// simulator works in fixed-point (`crate::quant`) and converts at the edge.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorBuf {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_buf_shape_checked() {
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 4]).is_ok());
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 5]).is_err());
    }

    #[test]
    fn tensor_buf_zeros() {
        let t = TensorBuf::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scalar_is_rank_zero() {
        let t = TensorBuf::scalar(3.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.data, vec![3.5]);
    }
}
