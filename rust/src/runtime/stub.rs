//! Stub runtime used when the `pjrt` feature is disabled.
//!
//! The default build environment has neither the vendored `xla` crate nor
//! the `xla_extension` shared library, so the PJRT-backed executor cannot
//! even link. This stub keeps the whole crate (simulator, compiler,
//! baselines, coordinator, benches) buildable and testable: constructing
//! an [`Executor`] succeeds, but loading or executing an *HLO* artifact
//! returns a typed error pointing at the `pjrt` feature. Callers that can
//! run without artifacts (tests, benches) detect this and skip.
//!
//! Since ISSUE 3 the stub is no longer execution-dead: the serving layer
//! can register a [`NativeDenoise`] surrogate under an artifact name
//! ([`Executor::register_native`]), after which `run_prepared` /
//! `run_batched` execute it on the host CPU. That is what lets tier-1
//! exercise the full batched/pipelined serving path offline.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::native::{BatchDispatch, NativeClassify, NativeDenoise};
use super::tensor_buf::TensorBuf;

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} requires the PJRT runtime, but this binary was built \
         without the `pjrt` feature (and the vendored `xla` crate) — \
         rebuild with `cargo build --features pjrt`"
    )
}

/// Stub executor: mirrors the PJRT executor's API. HLO paths fail with a
/// typed error; registered native surrogates execute for real.
pub struct Executor {
    natives: HashMap<String, NativeDenoise>,
    classifiers: HashMap<String, NativeClassify>,
}

impl Executor {
    /// Succeeds so construction sites stay uniform; HLO paths error.
    pub fn new() -> Result<Self> {
        Ok(Self {
            natives: HashMap::new(),
            classifiers: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        "native stub (pjrt feature disabled)".to_string()
    }

    /// Always an error: validates the path exists (so missing-artifact
    /// errors stay actionable), then reports the missing runtime.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        if !path.exists() {
            bail!("artifact `{name}` not found at {}", path.display());
        }
        Err(unavailable("compiling an HLO artifact"))
            .with_context(|| format!("loading artifact `{name}`"))
    }

    /// Register a host-CPU surrogate under an artifact name; subsequent
    /// `run_prepared`/`run_batched` calls on that name execute it.
    pub fn register_native(&mut self, name: &str, engine: NativeDenoise) {
        self.natives.insert(name.to_string(), engine);
    }

    /// Register a host-CPU classification surrogate (ISSUE 7) under an
    /// artifact name; `run_classifier` on that name executes it.
    pub fn register_classifier(&mut self, name: &str, engine: NativeClassify) {
        self.classifiers.insert(name.to_string(), engine);
    }

    /// True if anything executable is registered under `name`.
    pub fn has(&self, name: &str) -> bool {
        self.natives.contains_key(name) || self.classifiers.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .natives
            .keys()
            .chain(self.classifiers.keys())
            .map(|s| s.as_str())
            .collect();
        v.sort();
        v
    }

    pub fn run(&self, name: &str, _inputs: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        bail!("artifact `{name}` not loaded ({})", unavailable("execution"))
    }

    /// Host-side copy of the static inputs (the native surrogate reads
    /// them per dispatch; there is no device to convert them for).
    pub fn prepare(&self, tensors: &[TensorBuf]) -> Result<PreparedInputs> {
        Ok(PreparedInputs {
            tensors: tensors.to_vec(),
        })
    }

    pub fn run_prepared(
        &self,
        name: &str,
        dynamic: &[TensorBuf],
        prepared: &PreparedInputs,
    ) -> Result<Vec<TensorBuf>> {
        if let Some(engine) = self.natives.get(name) {
            return engine.run_dynamic(dynamic, &prepared.tensors);
        }
        bail!("artifact `{name}` not loaded ({})", unavailable("execution"))
    }

    /// Batched entry point: one `[B, ...]` × C-step dispatch (see
    /// [`BatchDispatch`]). Returns the updated images stacked `[B, ...]`.
    pub fn run_batched(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
    ) -> Result<TensorBuf> {
        if let Some(engine) = self.natives.get(name) {
            return engine.run_batched(d, &prepared.tensors);
        }
        bail!(
            "artifact `{name}` not loaded ({})",
            unavailable("batched execution")
        )
    }

    /// Fused resident scan (ISSUE 9): run the *entire* `steps` range of
    /// a batched dispatch in one engine call, with `beat` invoked per
    /// step for heartbeat liveness. Returns `Ok(true)` when a native
    /// engine executed it; `Ok(false)` when the artifact has no native
    /// engine, in which case the caller falls back to the chunked
    /// dispatch loop (the PJRT artifact path). Bit-identical to chunked
    /// execution of the same dispatch.
    pub fn run_scan_resident(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
        out: &mut TensorBuf,
        beat: &(dyn Fn() + Sync),
    ) -> Result<bool> {
        if let Some(engine) = self.natives.get(name) {
            out.shape.clone_from(&d.x.shape);
            out.data.resize(d.x.len(), 0.0);
            engine.run_scan_resident(d, &prepared.tensors, &mut out.data, beat)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Classification entry point (ISSUE 7): `B` stacked images →
    /// `[B, classes]` logits via the registered [`NativeClassify`]
    /// surrogate. Classification always executes natively — there is no
    /// HLO lowering for the classifier graphs, on either backend.
    pub fn run_classifier(
        &self,
        name: &str,
        batch: usize,
        x: &TensorBuf,
        prepared: &PreparedInputs,
    ) -> Result<TensorBuf> {
        if let Some(engine) = self.classifiers.get(name) {
            return engine.run_batch(batch, x, &prepared.tensors);
        }
        bail!("classifier `{name}` not registered")
    }

    /// In-place batched entry point (ISSUE 4): like
    /// [`Executor::run_batched`] but the result overwrites `out`,
    /// reusing its backing slab — zero allocations once the slab's
    /// capacity covers the batch.
    pub fn run_batched_into(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
        out: &mut TensorBuf,
    ) -> Result<()> {
        if let Some(engine) = self.natives.get(name) {
            out.shape.clone_from(&d.x.shape);
            out.data.resize(d.x.len(), 0.0);
            return engine.run_batched_into(d, &prepared.tensors, &mut out.data);
        }
        bail!(
            "artifact `{name}` not loaded ({})",
            unavailable("batched execution")
        )
    }
}

/// Host copies of pre-converted static inputs (see [`Executor::prepare`]).
pub struct PreparedInputs {
    tensors: Vec<TensorBuf>,
}

impl PreparedInputs {
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructs_but_refuses_to_run() {
        let exe = Executor::new().unwrap();
        assert!(exe.platform().contains("stub"));
        assert!(!exe.has("anything"));
        let err = exe
            .run("never-loaded", &[TensorBuf::zeros(&[1])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("not loaded"), "{err}");
    }

    #[test]
    fn stub_load_missing_file_mentions_path() {
        let mut exe = Executor::new().unwrap();
        let err = exe
            .load_hlo_text("x", Path::new("/nonexistent/x.hlo.txt"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("not found"), "{err}");
    }

    #[test]
    fn registered_native_executes_offline() {
        let mut exe = Executor::new().unwrap();
        exe.register_native("denoise", NativeDenoise::new(vec![1, 2, 2], 4));
        assert!(exe.has("denoise"));
        assert_eq!(exe.loaded_names(), vec!["denoise"]);
        let prepared = exe
            .prepare(&[TensorBuf::new(vec![2], vec![0.1, -0.1]).unwrap()])
            .unwrap();
        assert_eq!(prepared.len(), 1);
        let dynamic = vec![
            TensorBuf::new(vec![1, 2, 2], vec![0.1, 0.2, 0.3, 0.4]).unwrap(),
            TensorBuf::new(vec![4], vec![0.0, 0.1, 0.2, 0.3]).unwrap(),
            TensorBuf::scalar(1.01),
            TensorBuf::scalar(0.05),
            TensorBuf::scalar(0.0),
            TensorBuf::zeros(&[1, 2, 2]),
        ];
        let out = exe.run_prepared("denoise", &dynamic, &prepared).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![1, 2, 2]);
        // unknown names still error even with natives registered
        assert!(exe.run_prepared("other", &dynamic, &prepared).is_err());
    }

    #[test]
    fn registered_classifier_executes_offline() {
        let mut exe = Executor::new().unwrap();
        exe.register_classifier("resnet18", NativeClassify::new(vec![1, 2, 2], 3, 2));
        assert!(exe.has("resnet18"));
        assert_eq!(exe.loaded_names(), vec!["resnet18"]);
        let prepared = exe
            .prepare(&[TensorBuf::new(vec![2], vec![0.1, -0.1]).unwrap()])
            .unwrap();
        let x = TensorBuf::new(vec![2, 1, 2, 2], (0..8).map(|i| i as f32 * 0.1).collect())
            .unwrap();
        let out = exe.run_classifier("resnet18", 2, &x, &prepared).unwrap();
        assert_eq!(out.shape, vec![2, 3]);
        assert!(exe.run_classifier("other", 2, &x, &prepared).is_err());
    }
}
