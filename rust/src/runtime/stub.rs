//! Stub runtime used when the `pjrt` feature is disabled.
//!
//! The default build environment has neither the vendored `xla` crate nor
//! the `xla_extension` shared library, so the PJRT-backed executor cannot
//! even link. This stub keeps the whole crate (simulator, compiler,
//! baselines, coordinator, benches) buildable and testable: constructing
//! an [`Executor`] succeeds, but loading or executing an artifact returns
//! a typed error pointing at the `pjrt` feature. Callers that can run
//! without artifacts (tests, benches) detect this and skip.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor_buf::TensorBuf;

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} requires the PJRT runtime, but this binary was built \
         without the `pjrt` feature (and the vendored `xla` crate) — \
         rebuild with `cargo build --features pjrt`"
    )
}

/// Stub executor: mirrors the PJRT executor's API, fails on use.
pub struct Executor {
    _priv: (),
}

impl Executor {
    /// Succeeds so construction sites stay uniform; execution paths error.
    pub fn new() -> Result<Self> {
        Ok(Self { _priv: () })
    }

    pub fn platform(&self) -> String {
        "stub (pjrt feature disabled)".to_string()
    }

    /// Always an error: validates the path exists (so missing-artifact
    /// errors stay actionable), then reports the missing runtime.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        if !path.exists() {
            bail!("artifact `{name}` not found at {}", path.display());
        }
        Err(unavailable("compiling an HLO artifact"))
            .with_context(|| format!("loading artifact `{name}`"))
    }

    /// No executable can be loaded, so this is always false.
    pub fn has(&self, _name: &str) -> bool {
        false
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn run(&self, name: &str, _inputs: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        bail!("artifact `{name}` not loaded ({})", unavailable("execution"))
    }

    pub fn prepare(&self, _tensors: &[TensorBuf]) -> Result<PreparedInputs> {
        Err(unavailable("preparing device literals"))
    }

    pub fn run_prepared(
        &self,
        name: &str,
        _dynamic: &[TensorBuf],
        _prepared: &PreparedInputs,
    ) -> Result<Vec<TensorBuf>> {
        bail!("artifact `{name}` not loaded ({})", unavailable("execution"))
    }
}

/// Stub for pre-converted static inputs.
pub struct PreparedInputs {
    _priv: (),
}

impl PreparedInputs {
    pub fn len(&self) -> usize {
        0
    }

    pub fn is_empty(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructs_but_refuses_to_run() {
        let exe = Executor::new().unwrap();
        assert!(exe.platform().contains("stub"));
        assert!(!exe.has("anything"));
        let err = exe
            .run("never-loaded", &[TensorBuf::zeros(&[1])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("not loaded"), "{err}");
    }

    #[test]
    fn stub_load_missing_file_mentions_path() {
        let mut exe = Executor::new().unwrap();
        let err = exe
            .load_hlo_text("x", Path::new("/nonexistent/x.hlo.txt"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("not found"), "{err}");
    }
}
