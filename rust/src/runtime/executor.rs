//! Thin, safe wrapper over the `xla` crate's PJRT CPU client.
//!
//! One [`Executor`] holds the PJRT client plus every compiled executable
//! keyed by artifact name. All jax functions are lowered with
//! `return_tuple=True`, so execution results are unwrapped as tuples.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A host-side tensor: row-major `f32` data plus its shape.
///
/// This is the only tensor type that crosses the runtime boundary; the
/// simulator works in fixed-point (`crate::quant`) and converts at the edge.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorBuf {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorBuf {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            );
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<usize> = self.shape.clone();
        let lit = xla::Literal::vec1(&self.data);
        if dims.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims_i64)?)
        }
    }
}

/// Compiled-executable cache over a single PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a PJRT CPU client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// True if an executable has been loaded under `name`.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute artifact `name` on the given inputs; returns the tuple of
    /// outputs as host tensors.
    pub fn run(&self, name: &str, inputs: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.execute_refs(name, &refs)
    }

    /// Pre-convert static inputs (e.g. model weights) to device literals
    /// once, so the serving hot loop only converts the per-step tensors.
    /// §Perf: cut the U-net denoise step's host-side input preparation
    /// from 39 tensors (~530 KB) to 6 small ones per step.
    pub fn prepare(&self, tensors: &[TensorBuf]) -> Result<PreparedInputs> {
        Ok(PreparedInputs {
            lits: tensors
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<_>>()?,
        })
    }

    /// Execute with `dynamic` per-call inputs followed by `prepared`
    /// static inputs (in artifact argument order: dynamic first).
    pub fn run_prepared(
        &self,
        name: &str,
        dynamic: &[TensorBuf],
        prepared: &PreparedInputs,
    ) -> Result<Vec<TensorBuf>> {
        let dyn_lits: Vec<xla::Literal> = dynamic
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> =
            dyn_lits.iter().chain(prepared.lits.iter()).collect();
        self.execute_refs(name, &refs)
    }

    fn execute_refs(&self, name: &str, refs: &[&xla::Literal]) -> Result<Vec<TensorBuf>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact `{name}` not loaded"))?;
        let mut result = exe.execute::<&xla::Literal>(refs)?[0][0].to_literal_sync()?;
        // All artifacts are lowered with return_tuple=True.
        let parts = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            out.push(TensorBuf { shape: dims, data });
        }
        Ok(out)
    }
}

/// Pre-converted static inputs (see [`Executor::prepare`]).
pub struct PreparedInputs {
    lits: Vec<xla::Literal>,
}

impl PreparedInputs {
    pub fn len(&self) -> usize {
        self.lits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_buf_shape_checked() {
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 4]).is_ok());
        assert!(TensorBuf::new(vec![2, 2], vec![0.0; 5]).is_err());
    }

    #[test]
    fn tensor_buf_zeros() {
        let t = TensorBuf::zeros(&[3, 4]);
        assert_eq!(t.len(), 12);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }
}
