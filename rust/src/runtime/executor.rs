//! Thin, safe wrapper over the `xla` crate's PJRT CPU client (the real
//! runtime, compiled only with `--features pjrt`; see `super::stub`).
//!
//! One [`Executor`] holds the PJRT client plus every compiled executable
//! keyed by artifact name. All jax functions are lowered with
//! `return_tuple=True`, so execution results are unwrapped as tuples.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::tensor_buf::TensorBuf;

fn to_literal(t: &TensorBuf) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // rank-0: reshape to scalar
        Ok(lit.reshape(&[])?)
    } else {
        let dims_i64: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims_i64)?)
    }
}

/// Compiled-executable cache over a single PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a PJRT CPU client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// True if an executable has been loaded under `name`.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute artifact `name` on the given inputs; returns the tuple of
    /// outputs as host tensors.
    pub fn run(&self, name: &str, inputs: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.execute_refs(name, &refs)
    }

    /// Pre-convert static inputs (e.g. model weights) to device literals
    /// once, so the serving hot loop only converts the per-step tensors.
    /// §Perf: cut the U-net denoise step's host-side input preparation
    /// from 39 tensors (~530 KB) to 6 small ones per step.
    pub fn prepare(&self, tensors: &[TensorBuf]) -> Result<PreparedInputs> {
        Ok(PreparedInputs {
            lits: tensors.iter().map(to_literal).collect::<Result<_>>()?,
        })
    }

    /// Execute with `dynamic` per-call inputs followed by `prepared`
    /// static inputs (in artifact argument order: dynamic first).
    pub fn run_prepared(
        &self,
        name: &str,
        dynamic: &[TensorBuf],
        prepared: &PreparedInputs,
    ) -> Result<Vec<TensorBuf>> {
        let dyn_lits: Vec<xla::Literal> =
            dynamic.iter().map(to_literal).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> =
            dyn_lits.iter().chain(prepared.lits.iter()).collect();
        self.execute_refs(name, &refs)
    }

    fn execute_refs(&self, name: &str, refs: &[&xla::Literal]) -> Result<Vec<TensorBuf>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact `{name}` not loaded"))?;
        let mut result = exe.execute::<&xla::Literal>(refs)?[0][0].to_literal_sync()?;
        // All artifacts are lowered with return_tuple=True.
        let parts = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            out.push(TensorBuf { shape: dims, data });
        }
        Ok(out)
    }
}

/// Pre-converted static inputs (see [`Executor::prepare`]).
pub struct PreparedInputs {
    lits: Vec<xla::Literal>,
}

impl PreparedInputs {
    pub fn len(&self) -> usize {
        self.lits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }
}
