//! Thin, safe wrapper over the `xla` crate's PJRT CPU client (the real
//! runtime, compiled only with `--features pjrt`; see `super::stub`).
//!
//! One [`Executor`] holds the PJRT client plus every compiled executable
//! keyed by artifact name. All jax functions are lowered with
//! `return_tuple=True`, so execution results are unwrapped as tuples.
//!
//! The executor also carries the same native-surrogate registry as the
//! stub ([`Executor::register_native`]): a registered [`NativeDenoise`]
//! answers for names that have no compiled executable, so a PJRT build
//! can still serve offline workloads (and the serving layer is identical
//! across backends).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::native::{BatchDispatch, NativeClassify, NativeDenoise};
use super::tensor_buf::TensorBuf;

fn to_literal(t: &TensorBuf) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // rank-0: reshape to scalar
        Ok(lit.reshape(&[])?)
    } else {
        let dims_i64: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims_i64)?)
    }
}

/// Compiled-executable cache over a single PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    natives: HashMap<String, NativeDenoise>,
    classifiers: HashMap<String, NativeClassify>,
}

impl Executor {
    /// Create a PJRT CPU client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            executables: HashMap::new(),
            natives: HashMap::new(),
            classifiers: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Register a host-CPU surrogate under an artifact name; it answers
    /// `run_prepared`/`run_batched` for names without a compiled HLO.
    pub fn register_native(&mut self, name: &str, engine: NativeDenoise) {
        self.natives.insert(name.to_string(), engine);
    }

    /// Register a host-CPU classification surrogate (ISSUE 7). No HLO
    /// lowering exists for the classifier graphs, so classification
    /// executes natively even on the PJRT backend.
    pub fn register_classifier(&mut self, name: &str, engine: NativeClassify) {
        self.classifiers.insert(name.to_string(), engine);
    }

    /// True if anything executable is loaded under `name`.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
            || self.natives.contains_key(name)
            || self.classifiers.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .executables
            .keys()
            .chain(self.natives.keys())
            .chain(self.classifiers.keys())
            .map(|s| s.as_str())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Execute artifact `name` on the given inputs; returns the tuple of
    /// outputs as host tensors.
    pub fn run(&self, name: &str, inputs: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.execute_refs(name, &refs)
    }

    /// Pre-convert static inputs (e.g. model weights) to device literals
    /// once, so the serving hot loop only converts the per-step tensors.
    /// §Perf: cut the U-net denoise step's host-side input preparation
    /// from 39 tensors (~530 KB) to 6 small ones per step. A host copy is
    /// retained for the native-surrogate fallback.
    pub fn prepare(&self, tensors: &[TensorBuf]) -> Result<PreparedInputs> {
        Ok(PreparedInputs {
            lits: tensors.iter().map(to_literal).collect::<Result<_>>()?,
            host: tensors.to_vec(),
        })
    }

    /// Execute with `dynamic` per-call inputs followed by `prepared`
    /// static inputs (in artifact argument order: dynamic first).
    pub fn run_prepared(
        &self,
        name: &str,
        dynamic: &[TensorBuf],
        prepared: &PreparedInputs,
    ) -> Result<Vec<TensorBuf>> {
        if !self.executables.contains_key(name) {
            if let Some(engine) = self.natives.get(name) {
                return engine.run_dynamic(dynamic, &prepared.host);
            }
        }
        let dyn_lits: Vec<xla::Literal> =
            dynamic.iter().map(to_literal).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> =
            dyn_lits.iter().chain(prepared.lits.iter()).collect();
        self.execute_refs(name, &refs)
    }

    /// Batched entry point: one `[B, ...]` × C-step dispatch (see
    /// [`BatchDispatch`]). Resolution order:
    ///
    /// 1. a truly batched executable `"{name}__b{B}"` (stacked inputs,
    ///    one PJRT execution for the whole batch), if compiled;
    /// 2. the per-item scan executable `name` — inputs are unstacked and
    ///    executed per request (the chunk length must then match the
    ///    artifact's baked step count);
    /// 3. a registered native surrogate.
    ///
    /// Returns the updated images stacked `[B, ...]`.
    pub fn run_batched(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
    ) -> Result<TensorBuf> {
        let stacked_name = format!("{name}__b{}", d.batch);
        if self.executables.contains_key(&stacked_name) {
            let dynamic = [
                d.x.clone(),
                d.t_embs.clone(),
                d.coeffs.clone(),
                d.noises.clone(),
            ];
            let out = self.run_prepared(&stacked_name, &dynamic, prepared)?;
            return out
                .into_iter()
                .next()
                .context("batched artifact returned nothing");
        }
        if self.executables.contains_key(name) {
            return TensorBuf::stack(&self.run_batched_items(name, d, prepared)?);
        }
        if let Some(engine) = self.natives.get(name) {
            return engine.run_batched(d, &prepared.host);
        }
        bail!("artifact `{name}` not loaded")
    }

    /// Per-item fallback of the batched entry points: unstack the batch
    /// and execute the scan executable once per request, returning the
    /// B per-request outputs unstacked.
    fn run_batched_items(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
    ) -> Result<Vec<TensorBuf>> {
        let xs = d.x.unstack()?;
        let noises = d.noises.unstack()?;
        if xs.len() != d.batch || noises.len() != d.batch {
            bail!(
                "batched dispatch: leading dim {} != batch {}",
                xs.len(),
                d.batch
            );
        }
        let mut outs = Vec::with_capacity(xs.len());
        for (x_i, n_i) in xs.into_iter().zip(noises) {
            let dynamic = [x_i, d.t_embs.clone(), d.coeffs.clone(), n_i];
            let out = self.run_prepared(name, &dynamic, prepared)?;
            outs.push(
                out.into_iter()
                    .next()
                    .context("scan artifact returned nothing")?,
            );
        }
        Ok(outs)
    }

    /// In-place batched entry point (ISSUE 4): like
    /// [`Executor::run_batched`] but the result overwrites `out`, reusing
    /// its backing slab. The native-surrogate path is truly
    /// zero-allocation; compiled-executable paths still materialize
    /// literals at the XLA boundary and then copy into `out`, so the
    /// caller's pooled slab keeps rotating either way.
    pub fn run_batched_into(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
        out: &mut TensorBuf,
    ) -> Result<()> {
        let stacked_name = format!("{name}__b{}", d.batch);
        if !self.executables.contains_key(&stacked_name) {
            if self.executables.contains_key(name) {
                // per-item scan fallback: stack the B outputs straight
                // into the caller's slab, reusing its capacity
                let outs = self.run_batched_items(name, d, prepared)?;
                return TensorBuf::stack_into(&outs, out);
            }
            if let Some(engine) = self.natives.get(name) {
                out.shape.clone_from(&d.x.shape);
                out.data.resize(d.x.len(), 0.0);
                return engine.run_batched_into(d, &prepared.host, &mut out.data);
            }
        }
        // stacked-executable path: move the result into place (the
        // caller's old slab drops and this one enters the rotation)
        *out = self.run_batched(name, d, prepared)?;
        Ok(())
    }

    /// Fused resident scan (ISSUE 9): run the *entire* `steps` range of a
    /// batched dispatch in one engine call, with `beat` invoked per step
    /// for heartbeat liveness. Only the native surrogate can interleave
    /// host callbacks with execution, so this returns `Ok(true)` only
    /// when a native engine answered for `name` *and* no compiled
    /// executable shadows it; `Ok(false)` sends the caller down the
    /// chunked dispatch loop (which is how compiled artifacts execute).
    /// Bit-identical to chunked execution of the same dispatch.
    pub fn run_scan_resident(
        &self,
        name: &str,
        d: &BatchDispatch,
        prepared: &PreparedInputs,
        out: &mut TensorBuf,
        beat: &(dyn Fn() + Sync),
    ) -> Result<bool> {
        let stacked_name = format!("{name}__b{}", d.batch);
        if self.executables.contains_key(&stacked_name) || self.executables.contains_key(name) {
            return Ok(false);
        }
        if let Some(engine) = self.natives.get(name) {
            out.shape.clone_from(&d.x.shape);
            out.data.resize(d.x.len(), 0.0);
            engine.run_scan_resident(d, &prepared.host, &mut out.data, beat)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Classification entry point (ISSUE 7): `B` stacked images →
    /// `[B, classes]` logits via the registered [`NativeClassify`]
    /// surrogate (always native; see [`Executor::register_classifier`]).
    pub fn run_classifier(
        &self,
        name: &str,
        batch: usize,
        x: &TensorBuf,
        prepared: &PreparedInputs,
    ) -> Result<TensorBuf> {
        if let Some(engine) = self.classifiers.get(name) {
            return engine.run_batch(batch, x, &prepared.host);
        }
        bail!("classifier `{name}` not registered")
    }

    fn execute_refs(&self, name: &str, refs: &[&xla::Literal]) -> Result<Vec<TensorBuf>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact `{name}` not loaded"))?;
        let mut result = exe.execute::<&xla::Literal>(refs)?[0][0].to_literal_sync()?;
        // All artifacts are lowered with return_tuple=True.
        let parts = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            out.push(TensorBuf { shape: dims, data });
        }
        Ok(out)
    }
}

/// Pre-converted static inputs (see [`Executor::prepare`]).
pub struct PreparedInputs {
    lits: Vec<xla::Literal>,
    host: Vec<TensorBuf>,
}

impl PreparedInputs {
    pub fn len(&self) -> usize {
        self.lits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }
}
