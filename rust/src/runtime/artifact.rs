//! Artifact discovery: map artifact names to `artifacts/*.hlo.txt` files
//! produced by `make artifacts` (python/compile/aot.py).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

/// Description of one AOT artifact the runtime may load.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Logical name, e.g. `"unet_denoise_16"`.
    pub name: String,
    /// File path, e.g. `artifacts/unet_denoise_16.hlo.txt`.
    pub path: PathBuf,
}

/// A directory of `*.hlo.txt` artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }

    /// The default store: `$SF_MMCN_ARTIFACTS` or `./artifacts`.
    pub fn default_store() -> Self {
        let root = std::env::var("SF_MMCN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(root)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Path for a named artifact (does not check existence).
    pub fn path_for(&self, name: &str) -> PathBuf {
        self.root.join(format!("{name}.hlo.txt"))
    }

    /// Resolve a named artifact, failing with a build hint if missing.
    pub fn resolve(&self, name: &str) -> Result<ArtifactSpec> {
        let path = self.path_for(name);
        if !path.exists() {
            bail!(
                "artifact `{name}` not found at {} — run `make artifacts` first",
                path.display()
            );
        }
        Ok(ArtifactSpec {
            name: name.to_string(),
            path,
        })
    }

    /// Enumerate all artifacts present in the store.
    pub fn list(&self) -> Result<Vec<ArtifactSpec>> {
        let mut out = Vec::new();
        if !self.root.exists() {
            return Ok(out);
        }
        for entry in std::fs::read_dir(&self.root)? {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    out.push(ArtifactSpec {
                        name: stem.to_string(),
                        path: path.clone(),
                    });
                }
            }
        }
        out.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_naming() {
        let s = ArtifactStore::new("/tmp/arts");
        assert_eq!(
            s.path_for("unet"),
            PathBuf::from("/tmp/arts/unet.hlo.txt")
        );
    }

    #[test]
    fn missing_artifact_hints_make() {
        let s = ArtifactStore::new("/nonexistent-dir-xyz");
        let err = s.resolve("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn list_empty_when_absent() {
        let s = ArtifactStore::new("/nonexistent-dir-xyz");
        assert!(s.list().unwrap().is_empty());
    }
}
