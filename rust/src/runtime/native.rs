//! Native (host-CPU) surrogate denoise runtime — backend-independent.
//!
//! The real serving path executes AOT-compiled U-net artifacts through
//! PJRT. Neither the artifacts nor the PJRT runtime exist in the default
//! offline build, which previously left the whole coordinator layer
//! (queue → batcher → workers → DDPM loop) untestable in tier-1. This
//! module is a *performance-faithful surrogate* for the denoise artifacts:
//!
//! * **Functionally deterministic** — the same `(x, t_emb, coeffs, noise,
//!   params)` always produce bit-identical outputs, whether dispatched
//!   step-at-a-time, as a fused multi-step scan, or batched `[B, ...]`;
//!   the step update `x' = c1·(x − c2·eps) + σ·z` is the real DDPM
//!   reverse rule, with a cheap bounded surrogate for `eps_θ`.
//! * **Cost-shaped like a device dispatch** — every dispatch first folds
//!   the full prepared parameter set into a mixing digest (one pass over
//!   ~all weight scalars, the stand-in for per-dispatch weight streaming
//!   and executable-invocation overhead), then does O(pixels) work per
//!   image per step. Batching B requests or fusing T steps into one
//!   dispatch therefore amortizes the per-dispatch term exactly the way
//!   Server Flow amortizes weight streaming across a stream of work
//!   (paper §III), which is what the serve benchmarks measure offline.
//!
//! It makes no attempt to match the trained U-net's numerics — for that,
//! build with `--features pjrt` against real artifacts.
//!
//! Since ISSUE 7 the module also hosts [`NativeClassify`], the
//! classification surrogate for the multi-mode serving path (ResNet-18 /
//! VGG-16 alongside U-net denoise, the paper's multi-mode claim). It
//! follows the same two rules: deterministic bounded math with mutually
//! independent batch rows (batched ≡ per-request, bit for bit), and a
//! per-dispatch parameter digest plus per-image work scaled by the real
//! model's MAC count, so mixed-traffic benches see classification cost
//! in realistic proportion to denoise steps.

use anyhow::{bail, Result};

use super::tensor_buf::TensorBuf;

/// Fold a prepared parameter set into two bounded mixing coefficients.
/// Sequential f64 accumulation in manifest order keeps the result
/// bit-stable across dispatch shapes; running it *per dispatch* (not
/// once at prepare time) is deliberate — it is the surrogates'
/// per-dispatch weight-streaming / invocation overhead term.
fn param_digest(params: &[TensorBuf]) -> (f32, f32) {
    let mut s1 = 0.0f64;
    let mut s2 = 0.0f64;
    let mut n = 0usize;
    for t in params {
        for &v in &t.data {
            let v = v as f64;
            s1 += v;
            s2 += v * v;
        }
        n += t.data.len();
    }
    if n == 0 {
        return (0.71, 0.23);
    }
    let mean = s1 / n as f64;
    let rms = (s2 / n as f64).sqrt();
    let g0 = 0.75 + 0.5 * mean.tanh();
    let g1 = 0.2 + 0.3 * (rms / (1.0 + rms));
    (g0 as f32, g1 as f32)
}

/// One batched device dispatch: B requests × a chunk of `steps` reverse
/// timesteps, all tensors stacked. Rows of `t_embs`/`coeffs`/`noises` are
/// in *descending* t order (row 0 is the highest timestep of the chunk),
/// matching the fused-scan artifact convention.
#[derive(Debug)]
pub struct BatchDispatch<'a> {
    /// Number of requests stacked into this dispatch (B).
    pub batch: usize,
    /// Reverse timesteps executed by this dispatch (the chunk length C).
    pub steps: usize,
    /// Current images, `[B, c, h, w]`.
    pub x: &'a TensorBuf,
    /// Time embeddings per chunk step, `[C, time_dim]` (shared across B).
    pub t_embs: &'a TensorBuf,
    /// `(c1, c2, sigma)` rows per chunk step, `[C, 3]` (shared across B).
    pub coeffs: &'a TensorBuf,
    /// Per-request per-step noise draws, `[B, C, c, h, w]`.
    pub noises: &'a TensorBuf,
}

/// The 31-entry position table shared by every step-kernel variant.
#[inline]
fn step_pos_table() -> [f32; 31] {
    let mut pos = [0.0f32; 31];
    for (k, p) in pos.iter_mut().enumerate() {
        *p = (k as f32) * 0.021 - 0.31;
    }
    pos
}

/// One reverse DDPM step, in place — the exact scalar kernel, always
/// compiled. This is the default build's only step path and the
/// reference the `simd` feature's property suite compares against.
///
/// ISSUE 4: chunked 8-wide over bounds-check-free slice pairs so the
/// non-transcendental arithmetic autovectorizes; the per-element
/// expression tree (and therefore every output bit) is unchanged from
/// the original scalar loop.
pub fn step_kernel_scalar(
    x: &mut [f32],
    t_emb: &[f32],
    c: (f32, f32, f32),
    noise: &[f32],
    g: (f32, f32),
) {
    const W: usize = 8;
    const P: usize = 31;
    let e = t_emb.iter().copied().sum::<f32>() / t_emb.len().max(1) as f32;
    let (c1, c2, sigma) = c;
    let (g0, g1) = g;
    let bias = g1 * e;
    let pos = step_pos_table();
    let main = x.len() / W * W;
    let (xh, xt) = x.split_at_mut(main);
    let (nh, nt) = noise.split_at(main);
    for (ci, (xc, nc)) in xh
        .chunks_exact_mut(W)
        .zip(nh.chunks_exact(W))
        .enumerate()
    {
        let base = ci * W;
        for j in 0..W {
            let xi = xc[j];
            let eps = (g0 * xi + bias + pos[(base + j) % P]).tanh();
            xc[j] = c1 * (xi - c2 * eps) + sigma * nc[j];
        }
    }
    for (j, xi) in xt.iter_mut().enumerate() {
        let v = *xi;
        let eps = (g0 * v + bias + pos[(main + j) % P]).tanh();
        *xi = c1 * (v - c2 * eps) + sigma * nt[j];
    }
}

/// The `simd` build's step kernel: same preamble (`bias = g1·mean(emb)`,
/// position table) feeding the explicit-SIMD body in
/// [`crate::util::simd::step_kernel`]. Differs from
/// [`step_kernel_scalar`] only through the polynomial tanh — a bounded
/// ULP-level drift, tested by `tests/kernel_equiv.rs`.
#[cfg(feature = "simd")]
pub fn step_kernel_simd(
    x: &mut [f32],
    t_emb: &[f32],
    c: (f32, f32, f32),
    noise: &[f32],
    g: (f32, f32),
) {
    let e = t_emb.iter().copied().sum::<f32>() / t_emb.len().max(1) as f32;
    let (c1, c2, sigma) = c;
    let (g0, g1) = g;
    let bias = g1 * e;
    let pos = step_pos_table();
    crate::util::simd::step_kernel(x, noise, &pos, g0, bias, c1, c2, sigma);
}

/// The 31-entry rotating weight table shared by the classify kernels.
#[inline]
fn classify_wtab() -> [f32; 31] {
    let mut wtab = [0.0f32; 31];
    for (k, w) in wtab.iter_mut().enumerate() {
        *w = (k as f32) * 0.017 - 0.26;
    }
    wtab
}

/// One image → `classes` logits — the exact scalar classify kernel,
/// always compiled (see [`NativeClassify::forward_row`] for semantics).
pub fn classify_row_scalar(
    x: &[f32],
    g: (f32, f32),
    passes: usize,
    classes: usize,
    logits: &mut [f32],
) {
    const P: usize = 31;
    let (g0, g1) = g;
    let wtab = classify_wtab();
    let k_n = classes;
    let mut acc = vec![0.0f64; k_n];
    for p in 0..passes {
        let rot = p * 7 + 1;
        for (i, &v) in x.iter().enumerate() {
            let w = wtab[(i * rot + p) % P];
            acc[(i + p) % k_n] += (v * w) as f64;
        }
    }
    classify_head(&acc, x.len(), passes, g0, g1, &wtab, logits);
}

/// The `simd` build's classify kernel: vectorized products, identical
/// f64 accumulation order — **bit-identical** to
/// [`classify_row_scalar`] (asserted by `tests/kernel_equiv.rs`).
#[cfg(feature = "simd")]
pub fn classify_row_simd(
    x: &[f32],
    g: (f32, f32),
    passes: usize,
    classes: usize,
    logits: &mut [f32],
) {
    let (g0, g1) = g;
    let wtab = classify_wtab();
    let mut acc = vec![0.0f64; classes];
    crate::util::simd::classify_accumulate(x, &wtab, passes, classes, &mut acc);
    classify_head(&acc, x.len(), passes, g0, g1, &wtab, logits);
}

/// The bounded tanh head shared by both classify kernels: normalize the
/// per-class accumulators to O(1), mix with the parameter digest.
fn classify_head(
    acc: &[f64],
    n: usize,
    passes: usize,
    g0: f32,
    g1: f32,
    wtab: &[f32; 31],
    logits: &mut [f32],
) {
    // acc holds ~n*passes/k_n products of O(0.1) terms; normalize to
    // O(1) before the bounded head so logits stay discriminative
    let norm = (acc.len() as f64) / (n.max(1) as f64 * passes as f64);
    for (k, l) in logits.iter_mut().enumerate() {
        let a = (acc[k] * norm) as f32;
        *l = (g0 * a * 8.0 + g1 * wtab[k % 31]).tanh();
    }
}

/// The surrogate engine for one registered artifact name.
#[derive(Debug, Clone)]
pub struct NativeDenoise {
    pub img_shape: Vec<usize>,
    pub time_dim: usize,
}

impl NativeDenoise {
    pub fn new(img_shape: Vec<usize>, time_dim: usize) -> Self {
        Self {
            img_shape,
            time_dim,
        }
    }

    fn pixels(&self) -> usize {
        self.img_shape.iter().product()
    }

    /// The per-dispatch overhead term (see [`param_digest`]).
    fn digest(params: &[TensorBuf]) -> (f32, f32) {
        param_digest(params)
    }

    /// One reverse step, in place. `eps = tanh(g0·x + g1·mean(emb) + pos)`
    /// is bounded, so the served images stay bounded like a trained
    /// denoiser's; the update itself is the exact DDPM rule.
    ///
    /// Default build dispatches the exact scalar kernel
    /// ([`step_kernel_scalar`]); `--features simd` swaps in the
    /// explicit-SIMD polynomial-tanh path ([`step_kernel_simd`], bounded
    /// ULP drift, see EXPERIMENTS.md §Kernels).
    fn step_into(x: &mut [f32], t_emb: &[f32], c: (f32, f32, f32), noise: &[f32], g: (f32, f32)) {
        #[cfg(not(feature = "simd"))]
        step_kernel_scalar(x, t_emb, c, noise, g);
        #[cfg(feature = "simd")]
        step_kernel_simd(x, t_emb, c, noise, g);
    }

    /// Step-artifact semantics: `dynamic = [x, t_emb, c1, c2, sigma, noise]`.
    pub fn run_step(&self, dynamic: &[TensorBuf], params: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        let n = self.pixels();
        if dynamic.len() != 6 {
            bail!("native step dispatch wants 6 inputs, got {}", dynamic.len());
        }
        if dynamic[0].len() != n || dynamic[5].len() != n {
            bail!(
                "native step dispatch: image/noise length {}/{} != {n}",
                dynamic[0].len(),
                dynamic[5].len()
            );
        }
        let g = Self::digest(params);
        let c = (
            dynamic[2].data[0],
            dynamic[3].data[0],
            dynamic[4].data[0],
        );
        let mut x = dynamic[0].clone();
        Self::step_into(&mut x.data, &dynamic[1].data, c, &dynamic[5].data, g);
        Ok(vec![x])
    }

    /// Scan-artifact semantics: `dynamic = [x, t_embs[C,td], coeffs[C,3],
    /// noises[C,...]]` — the whole chunk in one dispatch (digest once).
    pub fn run_scan(&self, dynamic: &[TensorBuf], params: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        let n = self.pixels();
        if dynamic.len() != 4 {
            bail!("native scan dispatch wants 4 inputs, got {}", dynamic.len());
        }
        let steps = *dynamic[1].shape.first().unwrap_or(&0);
        if steps == 0 || dynamic[1].shape != vec![steps, self.time_dim] {
            bail!(
                "native scan dispatch: t_embs shape {:?} != [T, {}]",
                dynamic[1].shape,
                self.time_dim
            );
        }
        if dynamic[2].shape != vec![steps, 3] {
            bail!(
                "native scan dispatch: coeffs shape {:?} != [{steps}, 3]",
                dynamic[2].shape
            );
        }
        if dynamic[0].len() != n || dynamic[3].len() != steps * n {
            bail!(
                "native scan dispatch: image/noises length {}/{} != {n}/{}",
                dynamic[0].len(),
                dynamic[3].len(),
                steps * n
            );
        }
        let g = Self::digest(params);
        let td = self.time_dim;
        let mut x = dynamic[0].clone();
        for r in 0..steps {
            let emb = &dynamic[1].data[r * td..(r + 1) * td];
            let c = (
                dynamic[2].data[r * 3],
                dynamic[2].data[r * 3 + 1],
                dynamic[2].data[r * 3 + 2],
            );
            let noise = &dynamic[3].data[r * n..(r + 1) * n];
            Self::step_into(&mut x.data, emb, c, noise, g);
        }
        Ok(vec![x])
    }

    /// Dispatch on the artifact's input arity (6 → step, 4 → scan).
    pub fn run_dynamic(&self, dynamic: &[TensorBuf], params: &[TensorBuf]) -> Result<Vec<TensorBuf>> {
        match dynamic.len() {
            6 => self.run_step(dynamic, params),
            4 => self.run_scan(dynamic, params),
            other => bail!(
                "native denoise dispatch wants 6 (step) or 4 (scan) inputs, got {other}"
            ),
        }
    }

    /// Batched entry point: B stacked requests × a C-step chunk in ONE
    /// dispatch — digest once, then per-image per-step work. Returns the
    /// updated images stacked `[B, c, h, w]` (allocating wrapper over
    /// the same row kernel as [`NativeDenoise::run_batched_into`]; the
    /// initial clone of `x` is the seed copy, so no buffer is written
    /// twice).
    pub fn run_batched(&self, d: &BatchDispatch, params: &[TensorBuf]) -> Result<TensorBuf> {
        self.validate_batched(d)?;
        let mut out = TensorBuf {
            shape: d.x.shape.clone(),
            data: d.x.data.clone(),
        };
        self.denoise_rows(d, params, &mut out.data);
        Ok(out)
    }

    /// Zero-allocation batched entry point (ISSUE 4): identical math to
    /// [`NativeDenoise::run_batched`], but the updated images are written
    /// into the caller's `out` slab (`B * pixels` elements — the pooled
    /// serving lane rotates two such slabs through the chunk loop).
    ///
    /// Rows (requests) are mutually independent, so large dispatches fan
    /// out across threads; per-row arithmetic is unchanged, keeping the
    /// result bit-identical at any thread count.
    pub fn run_batched_into(
        &self,
        d: &BatchDispatch,
        params: &[TensorBuf],
        out: &mut [f32],
    ) -> Result<()> {
        let n = self.validate_batched(d)?;
        if out.len() != d.batch * n {
            bail!(
                "batched dispatch: out slab {} != B*{n} (B = {})",
                out.len(),
                d.batch
            );
        }
        out.copy_from_slice(&d.x.data);
        self.denoise_rows(d, params, out);
        Ok(())
    }

    /// Shape/size validation shared by the batched entry points; returns
    /// the per-image pixel count.
    fn validate_batched(&self, d: &BatchDispatch) -> Result<usize> {
        let n = self.pixels();
        let (b, steps) = (d.batch, d.steps);
        if b == 0 || steps == 0 {
            bail!("empty batched dispatch (batch {b}, steps {steps})");
        }
        if n == 0 {
            bail!("native denoise: empty image shape {:?}", self.img_shape);
        }
        if d.x.len() != b * n {
            bail!("batched dispatch: x length {} != B*{n} (B = {b})", d.x.len());
        }
        if d.t_embs.shape != vec![steps, self.time_dim] {
            bail!(
                "batched dispatch: t_embs shape {:?} != [{steps}, {}]",
                d.t_embs.shape,
                self.time_dim
            );
        }
        if d.coeffs.shape != vec![steps, 3] {
            bail!(
                "batched dispatch: coeffs shape {:?} != [{steps}, 3]",
                d.coeffs.shape
            );
        }
        if d.noises.len() != b * steps * n {
            bail!(
                "batched dispatch: noises length {} != B*C*{n} (B = {b}, C = {steps})",
                d.noises.len()
            );
        }
        Ok(n)
    }

    /// Fused all-timesteps resident scan (ISSUE 9): identical math to
    /// [`NativeDenoise::run_batched_into`] — each request's image stays
    /// hot in the `out` slab across the whole reverse trajectory, with
    /// the full noise tensor consumed in place (no per-chunk re-gather or
    /// slab ping-pong at the serving layer) — plus a per-step `beat`
    /// callback so the lane keeps publishing heartbeat liveness with the
    /// same cadence the chunked path gets from per-chunk dispatches.
    /// Beats may arrive from any fanout thread; `ShardPulse` counts them
    /// relaxed, so ordering is irrelevant.
    pub fn run_scan_resident(
        &self,
        d: &BatchDispatch,
        params: &[TensorBuf],
        out: &mut [f32],
        beat: &(dyn Fn() + Sync),
    ) -> Result<()> {
        let n = self.validate_batched(d)?;
        if out.len() != d.batch * n {
            bail!(
                "resident scan: out slab {} != B*{n} (B = {})",
                out.len(),
                d.batch
            );
        }
        out.copy_from_slice(&d.x.data);
        self.denoise_rows_with(d, params, out, Some(beat));
        Ok(())
    }

    /// The batched row kernel: `out` must already be seeded with the
    /// stacked input images (validated by the entry points above).
    fn denoise_rows(&self, d: &BatchDispatch, params: &[TensorBuf], out: &mut [f32]) {
        self.denoise_rows_with(d, params, out, None);
    }

    /// [`NativeDenoise::denoise_rows`] with an optional per-step liveness
    /// callback (the resident scan's heartbeat). The callback sits
    /// outside the per-element arithmetic, so `beat: None` and
    /// `beat: Some(..)` produce bit-identical slabs.
    fn denoise_rows_with(
        &self,
        d: &BatchDispatch,
        params: &[TensorBuf],
        out: &mut [f32],
        beat: Option<&(dyn Fn() + Sync)>,
    ) {
        let n = self.pixels();
        let (b, steps) = (d.batch, d.steps);
        let g = Self::digest(params);
        let td = self.time_dim;
        let denoise_row = |x: &mut [f32], i: usize| {
            for r in 0..steps {
                let emb = &d.t_embs.data[r * td..(r + 1) * td];
                let c = (
                    d.coeffs.data[r * 3],
                    d.coeffs.data[r * 3 + 1],
                    d.coeffs.data[r * 3 + 2],
                );
                let noise = &d.noises.data[(i * steps + r) * n..(i * steps + r + 1) * n];
                Self::step_into(x, emb, c, noise, g);
                if let Some(beat) = beat {
                    beat();
                }
            }
        };
        let threads = fanout_threads(b, steps * n);
        if threads <= 1 {
            for (i, x) in out.chunks_mut(n).enumerate() {
                denoise_row(x, i);
            }
        } else {
            let rows_per = b.div_ceil(threads);
            std::thread::scope(|s| {
                for (shard, xs) in out.chunks_mut(rows_per * n).enumerate() {
                    let denoise_row = &denoise_row;
                    s.spawn(move || {
                        for (j, x) in xs.chunks_mut(n).enumerate() {
                            denoise_row(x, shard * rows_per + j);
                        }
                    });
                }
            });
        }
    }
}

/// Deterministic host-CPU classification surrogate (ISSUE 7): the
/// multi-mode analogue of [`NativeDenoise`] for the ResNet-18 / VGG-16
/// serving modes.
///
/// Same surrogate contract:
///
/// * **Deterministic and bounded** — logits are a pure function of
///   `(x, params)`; every batch row is computed independently with a
///   fixed accumulation order, so batched and per-request execution are
///   bit-identical at any batch size or thread count.
/// * **Cost-shaped like the real model** — every dispatch pays the
///   `param_digest` weight-streaming term, then `passes` full sweeps
///   over each image. The server derives `passes` from the model graph's
///   MAC count, so VGG-16 requests cost proportionally more host work
///   than ResNet-18 requests, the way they would on the accelerator.
#[derive(Debug, Clone)]
pub struct NativeClassify {
    /// Input image shape `[c, h, w]`.
    pub img_shape: Vec<usize>,
    /// Output logit count.
    pub classes: usize,
    /// Sweeps over the image per request (the MAC-count cost knob).
    pub passes: usize,
}

impl NativeClassify {
    pub fn new(img_shape: Vec<usize>, classes: usize, passes: usize) -> Self {
        Self {
            img_shape,
            classes,
            passes: passes.max(1),
        }
    }

    fn pixels(&self) -> usize {
        self.img_shape.iter().product()
    }

    /// One image → `classes` logits. Each pass scatters the image into
    /// the class accumulators under a rotating weight table (the same
    /// 31-entry position-table idiom as the denoise kernel); the mean
    /// accumulator then maps through a bounded tanh head mixed with the
    /// parameter digest. Fixed sequential order — bit-stable everywhere.
    fn forward_row(&self, x: &[f32], g: (f32, f32), logits: &mut [f32]) {
        #[cfg(not(feature = "simd"))]
        classify_row_scalar(x, g, self.passes, self.classes, logits);
        // the simd classify path is bit-identical (same products, same
        // accumulation order), so this dispatch never changes served bits
        #[cfg(feature = "simd")]
        classify_row_simd(x, g, self.passes, self.classes, logits);
    }

    /// Shape/size validation shared by the batched entry points; returns
    /// the per-image pixel count.
    fn validate_batch(&self, batch: usize, x: &TensorBuf) -> Result<usize> {
        let n = self.pixels();
        if batch == 0 {
            bail!("empty classification dispatch");
        }
        if n == 0 || self.classes == 0 {
            bail!(
                "native classify: degenerate engine (shape {:?}, {} classes)",
                self.img_shape,
                self.classes
            );
        }
        if x.len() != batch * n {
            bail!(
                "classification dispatch: x length {} != B*{n} (B = {batch})",
                x.len()
            );
        }
        Ok(n)
    }

    /// Batched forward: B stacked images `[B, c, h, w]` → logits
    /// `[B, classes]` in one dispatch (digest once).
    pub fn run_batch(
        &self,
        batch: usize,
        x: &TensorBuf,
        params: &[TensorBuf],
    ) -> Result<TensorBuf> {
        let mut out = TensorBuf::zeros(&[batch, self.classes]);
        self.run_batch_into(batch, x, params, &mut out.data)?;
        Ok(out)
    }

    /// Zero-allocation batched forward: logits written into the caller's
    /// `out` slab (`B * classes` elements). Rows are independent, so
    /// large dispatches fan out across threads bit-identically.
    pub fn run_batch_into(
        &self,
        batch: usize,
        x: &TensorBuf,
        params: &[TensorBuf],
        out: &mut [f32],
    ) -> Result<()> {
        let n = self.validate_batch(batch, x)?;
        if out.len() != batch * self.classes {
            bail!(
                "classification dispatch: out slab {} != B*{} (B = {batch})",
                out.len(),
                self.classes
            );
        }
        let g = param_digest(params);
        let k_n = self.classes;
        let threads = fanout_threads(batch, self.passes * n);
        if threads <= 1 {
            for (i, logits) in out.chunks_mut(k_n).enumerate() {
                self.forward_row(&x.data[i * n..(i + 1) * n], g, logits);
            }
        } else {
            let rows_per = batch.div_ceil(threads);
            std::thread::scope(|s| {
                for (shard, ls) in out.chunks_mut(rows_per * k_n).enumerate() {
                    s.spawn(move || {
                        for (j, logits) in ls.chunks_mut(k_n).enumerate() {
                            let i = shard * rows_per + j;
                            self.forward_row(&x.data[i * n..(i + 1) * n], g, logits);
                        }
                    });
                }
            });
        }
        Ok(())
    }
}

/// How many threads to fan a batched dispatch across: bounded by the
/// hardware, the row count, and a minimum per-thread workload so small
/// dispatches stay on the calling thread (spawning costs ~tens of µs).
///
/// `SF_MMCN_FANOUT_THREADS=<n>` overrides the policy outright (clamped
/// to 1..=64) — the kernel-equivalence suite uses it to prove the fanout
/// is bit-identical at forced thread counts, and operators can use it to
/// pin a sweep's parallelism. Read per call (it's once per dispatch, not
/// per element) so tests can vary it within one process.
fn fanout_threads(batch: usize, work_per_row: usize) -> usize {
    const MIN_WORK_PER_THREAD: usize = 1 << 15;
    if let Ok(v) = std::env::var("SF_MMCN_FANOUT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(64);
            }
        }
    }
    if batch < 2 {
        return 1;
    }
    let hw = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let by_work = (batch * work_per_row / MIN_WORK_PER_THREAD).max(1);
    hw.min(batch).min(by_work).min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> NativeDenoise {
        NativeDenoise::new(vec![1, 4, 4], 8)
    }

    fn params() -> Vec<TensorBuf> {
        vec![
            TensorBuf::new(vec![3], vec![0.1, -0.2, 0.3]).unwrap(),
            TensorBuf::new(vec![2, 2], vec![0.05, 0.0, -0.1, 0.2]).unwrap(),
        ]
    }

    fn step_inputs(seed: f32) -> Vec<TensorBuf> {
        let x: Vec<f32> = (0..16).map(|i| seed + i as f32 * 0.01).collect();
        let emb: Vec<f32> = (0..8).map(|i| (i as f32) * 0.1).collect();
        let noise: Vec<f32> = (0..16).map(|i| (i as f32) * 0.002 - 0.01).collect();
        vec![
            TensorBuf::new(vec![1, 4, 4], x).unwrap(),
            TensorBuf::new(vec![8], emb).unwrap(),
            TensorBuf::scalar(1.01),
            TensorBuf::scalar(0.05),
            TensorBuf::scalar(0.1),
            TensorBuf::new(vec![1, 4, 4], noise).unwrap(),
        ]
    }

    #[test]
    fn step_deterministic_and_bounded() {
        let e = engine();
        let a = e.run_step(&step_inputs(0.3), &params()).unwrap();
        let b = e.run_step(&step_inputs(0.3), &params()).unwrap();
        assert_eq!(a[0], b[0]);
        assert!(a[0].data.iter().all(|v| v.abs() < 10.0));
        let c = e.run_step(&step_inputs(0.4), &params()).unwrap();
        assert_ne!(a[0].data, c[0].data);
    }

    #[test]
    fn scan_matches_repeated_steps_bitwise() {
        let e = engine();
        let p = params();
        let steps = 3;
        // scan inputs for 3 steps (descending t rows)
        let x0: Vec<f32> = (0..16).map(|i| 0.2 + i as f32 * 0.03).collect();
        let mut t_embs = Vec::new();
        let mut coeffs = Vec::new();
        let mut noises = Vec::new();
        for r in 0..steps {
            t_embs.extend((0..8).map(|i| (i + r) as f32 * 0.07));
            coeffs.extend([1.005, 0.04, if r + 1 < steps { 0.08 } else { 0.0 }]);
            noises.extend((0..16).map(|i| (i as f32 + r as f32) * 0.001));
        }
        let scan_dyn = vec![
            TensorBuf::new(vec![1, 4, 4], x0.clone()).unwrap(),
            TensorBuf::new(vec![steps, 8], t_embs.clone()).unwrap(),
            TensorBuf::new(vec![steps, 3], coeffs.clone()).unwrap(),
            TensorBuf::new(vec![steps, 1, 4, 4], noises.clone()).unwrap(),
        ];
        let fused = e.run_scan(&scan_dyn, &p).unwrap();

        // same three steps dispatched one at a time
        let mut x = TensorBuf::new(vec![1, 4, 4], x0).unwrap();
        for r in 0..steps {
            let dynamic = vec![
                x.clone(),
                TensorBuf::new(vec![8], t_embs[r * 8..(r + 1) * 8].to_vec()).unwrap(),
                TensorBuf::scalar(coeffs[r * 3]),
                TensorBuf::scalar(coeffs[r * 3 + 1]),
                TensorBuf::scalar(coeffs[r * 3 + 2]),
                TensorBuf::new(vec![1, 4, 4], noises[r * 16..(r + 1) * 16].to_vec()).unwrap(),
            ];
            x = e.run_step(&dynamic, &p).unwrap().remove(0);
        }
        assert_eq!(fused[0].data, x.data, "scan and step paths must be bit-identical");
    }

    #[test]
    fn batched_matches_solo_scan_bitwise() {
        let e = engine();
        let p = params();
        let steps = 2;
        let imgs: Vec<Vec<f32>> = (0..3)
            .map(|b| (0..16).map(|i| (b * 16 + i) as f32 * 0.015 - 0.1).collect())
            .collect();
        let t_embs: Vec<f32> = (0..steps * 8).map(|i| i as f32 * 0.03).collect();
        let coeffs: Vec<f32> = vec![1.01, 0.05, 0.1, 1.002, 0.03, 0.0];
        let per_noise: Vec<Vec<f32>> = (0..3)
            .map(|b| (0..steps * 16).map(|i| (b + i) as f32 * 0.001).collect())
            .collect();

        let x_stack =
            TensorBuf::new(vec![3, 1, 4, 4], imgs.concat()).unwrap();
        let noise_stack =
            TensorBuf::new(vec![3, steps, 1, 4, 4], per_noise.concat()).unwrap();
        let t_embs_t = TensorBuf::new(vec![steps, 8], t_embs.clone()).unwrap();
        let coeffs_t = TensorBuf::new(vec![steps, 3], coeffs.clone()).unwrap();
        let d = BatchDispatch {
            batch: 3,
            steps,
            x: &x_stack,
            t_embs: &t_embs_t,
            coeffs: &coeffs_t,
            noises: &noise_stack,
        };
        let batched = e.run_batched(&d, &p).unwrap();
        let parts = batched.unstack().unwrap();

        for b in 0..3 {
            let scan_dyn = vec![
                TensorBuf::new(vec![1, 4, 4], imgs[b].clone()).unwrap(),
                t_embs_t.clone(),
                coeffs_t.clone(),
                TensorBuf::new(vec![steps, 1, 4, 4], per_noise[b].clone()).unwrap(),
            ];
            let solo = e.run_scan(&scan_dyn, &p).unwrap();
            assert_eq!(parts[b].data, solo[0].data, "request {b} diverged under batching");
        }
    }

    #[test]
    fn run_batched_into_matches_allocating_path() {
        let e = engine();
        let p = params();
        let steps = 2;
        // large-ish batch so the fanout path is at least reachable
        let b = 5;
        let x: Vec<f32> = (0..b * 16).map(|i| (i as f32) * 0.013 - 0.4).collect();
        let t_embs: Vec<f32> = (0..steps * 8).map(|i| i as f32 * 0.05).collect();
        let coeffs: Vec<f32> = vec![1.01, 0.05, 0.1, 1.002, 0.03, 0.0];
        let noises: Vec<f32> = (0..b * steps * 16).map(|i| (i as f32) * 0.0007).collect();
        let x_t = TensorBuf::new(vec![b, 1, 4, 4], x).unwrap();
        let noise_t = TensorBuf::new(vec![b, steps, 1, 4, 4], noises).unwrap();
        let te_t = TensorBuf::new(vec![steps, 8], t_embs).unwrap();
        let co_t = TensorBuf::new(vec![steps, 3], coeffs).unwrap();
        let d = BatchDispatch {
            batch: b,
            steps,
            x: &x_t,
            t_embs: &te_t,
            coeffs: &co_t,
            noises: &noise_t,
        };
        let alloc = e.run_batched(&d, &p).unwrap();
        let mut out = vec![0.0f32; b * 16];
        e.run_batched_into(&d, &p, &mut out).unwrap();
        assert_eq!(out, alloc.data, "in-place and allocating paths must agree");
        // wrong-sized out slab rejected
        let mut short = vec![0.0f32; b * 16 - 1];
        assert!(e.run_batched_into(&d, &p, &mut short).is_err());
    }

    #[test]
    fn threaded_fanout_bit_identical_to_solo_scans() {
        // Big enough that fanout_threads exceeds 1 on multi-core hosts
        // (4 rows x 8 steps x 4096 px = 128 Ki elements of row work);
        // rows are independent, so any thread count must reproduce the
        // solo per-row scan bit for bit.
        let e = NativeDenoise::new(vec![1, 64, 64], 8);
        let p = params();
        let (b, steps, n) = (4usize, 8usize, 4096usize);
        let x: Vec<f32> = (0..b * n).map(|i| ((i % 97) as f32) * 0.011 - 0.5).collect();
        let t_embs: Vec<f32> = (0..steps * 8).map(|i| (i as f32) * 0.02 - 0.07).collect();
        let mut coeffs = Vec::new();
        for r in 0..steps {
            coeffs.extend([1.003, 0.04, if r + 1 < steps { 0.06 } else { 0.0 }]);
        }
        let noises: Vec<f32> = (0..b * steps * n)
            .map(|i| ((i % 113) as f32) * 0.0008 - 0.04)
            .collect();
        let x_t = TensorBuf::new(vec![b, 1, 64, 64], x.clone()).unwrap();
        let te_t = TensorBuf::new(vec![steps, 8], t_embs).unwrap();
        let co_t = TensorBuf::new(vec![steps, 3], coeffs).unwrap();
        let no_t = TensorBuf::new(vec![b, steps, 1, 64, 64], noises.clone()).unwrap();
        let d = BatchDispatch {
            batch: b,
            steps,
            x: &x_t,
            t_embs: &te_t,
            coeffs: &co_t,
            noises: &no_t,
        };
        let mut out = vec![0.0f32; b * n];
        e.run_batched_into(&d, &p, &mut out).unwrap();
        for i in 0..b {
            let scan_dyn = vec![
                TensorBuf::new(vec![1, 64, 64], x[i * n..(i + 1) * n].to_vec()).unwrap(),
                te_t.clone(),
                co_t.clone(),
                TensorBuf::new(
                    vec![steps, 1, 64, 64],
                    noises[i * steps * n..(i + 1) * steps * n].to_vec(),
                )
                .unwrap(),
            ];
            let solo = e.run_scan(&scan_dyn, &p).unwrap();
            assert_eq!(
                out[i * n..(i + 1) * n],
                solo[0].data[..],
                "row {i} diverged under threaded fanout"
            );
        }
    }

    #[test]
    fn shape_mismatches_rejected() {
        let e = engine();
        let p = params();
        let mut bad = step_inputs(0.1);
        bad[0] = TensorBuf::zeros(&[1, 2, 2]);
        assert!(e.run_step(&bad, &p).is_err());
        assert!(e.run_dynamic(&step_inputs(0.1)[..3], &p).is_err());
    }

    fn classify_engine() -> NativeClassify {
        NativeClassify::new(vec![3, 8, 8], 10, 4)
    }

    fn images(batch: usize, seed: f32) -> TensorBuf {
        let n = 3 * 8 * 8;
        let data: Vec<f32> = (0..batch * n)
            .map(|i| seed + (i as f32 * 0.013).sin() * 0.4)
            .collect();
        TensorBuf::new(vec![batch, 3, 8, 8], data).unwrap()
    }

    #[test]
    fn classify_deterministic_bounded_and_input_sensitive() {
        let e = classify_engine();
        let p = params();
        let a = e.run_batch(2, &images(2, 0.3), &p).unwrap();
        let b = e.run_batch(2, &images(2, 0.3), &p).unwrap();
        assert_eq!(a.data, b.data);
        assert_eq!(a.shape, vec![2, 10]);
        assert!(a.data.iter().all(|v| v.abs() < 1.0), "tanh head bounds logits");
        let c = e.run_batch(2, &images(2, 0.4), &p).unwrap();
        assert_ne!(a.data, c.data, "logits must depend on the input image");
        // and on the parameter digest
        let d = e.run_batch(2, &images(2, 0.3), &[]).unwrap();
        assert_ne!(a.data, d.data, "logits must depend on the params");
        // rows aren't all the same value
        assert!(a.data[..10].iter().any(|v| (v - a.data[0]).abs() > 1e-6));
    }

    #[test]
    fn classify_batched_matches_solo_bitwise() {
        // Large enough batch to cross the thread-fanout path on big rows:
        // use a heavier pass count so work exceeds MIN_WORK_PER_THREAD.
        let e = NativeClassify::new(vec![3, 32, 32], 10, 64);
        let p = params();
        let b = 6;
        let n = 3 * 32 * 32;
        let all: Vec<f32> = (0..b * n)
            .map(|i| ((i as f32) * 0.007).cos() * 0.5)
            .collect();
        let x = TensorBuf::new(vec![b, 3, 32, 32], all.clone()).unwrap();
        let batched = e.run_batch(b, &x, &p).unwrap();
        for i in 0..b {
            let solo_x =
                TensorBuf::new(vec![1, 3, 32, 32], all[i * n..(i + 1) * n].to_vec()).unwrap();
            let solo = e.run_batch(1, &solo_x, &p).unwrap();
            assert_eq!(
                batched.data[i * 10..(i + 1) * 10],
                solo.data[..],
                "classify row {i} diverged between batched and per-request"
            );
        }
    }

    #[test]
    fn classify_pass_count_shapes_output_and_cost() {
        let e1 = NativeClassify::new(vec![3, 8, 8], 10, 1);
        let e2 = NativeClassify::new(vec![3, 8, 8], 10, 8);
        let p = params();
        let a = e1.run_batch(1, &images(1, 0.2), &p).unwrap();
        let b = e2.run_batch(1, &images(1, 0.2), &p).unwrap();
        assert_ne!(a.data, b.data, "pass count is part of the function");
        // passes=0 clamps to 1
        let e0 = NativeClassify::new(vec![3, 8, 8], 10, 0);
        assert_eq!(e0.passes, 1);
        let c = e0.run_batch(1, &images(1, 0.2), &p).unwrap();
        assert_eq!(a.data, c.data);
    }

    #[test]
    fn classify_shape_mismatches_rejected() {
        let e = classify_engine();
        let p = params();
        assert!(e.run_batch(0, &images(1, 0.1), &p).is_err());
        assert!(e.run_batch(2, &images(1, 0.1), &p).is_err());
        let mut short = vec![0.0f32; 5];
        assert!(e
            .run_batch_into(1, &images(1, 0.1), &p, &mut short)
            .is_err());
        let degenerate = NativeClassify::new(vec![], 10, 1);
        assert!(degenerate.run_batch(1, &TensorBuf::zeros(&[0]), &p).is_err());
    }
}
