//! Arena-backed `f32` buffer pool — the reuse-over-reallocate substrate
//! for the zero-steady-state-allocation serving hot path (ISSUE 4).
//!
//! SF-MMCN's server-flow discipline keeps a small fixed resource set
//! saturated by streaming work through it instead of provisioning per
//! operation (paper §III); this pool is the software analogue for host
//! memory. A worker lane leases slabs for its batch tensors, executes,
//! and returns them; after a short warmup every lease is served from the
//! free list and the allocator drops out of the hot loop entirely.
//!
//! Design points:
//!
//! * **Capacity-based best fit** — a lease asks for a length and gets the
//!   smallest retained slab whose *capacity* covers it, so the shrinking
//!   tail batches of a draining queue keep hitting the slabs their bigger
//!   predecessors allocated.
//! * **Zeroed leases by default** — [`BufferPool::lease`] hands back a
//!   slab filled with zeros, so a recycled buffer is indistinguishable
//!   from a fresh `vec![0.0; n]` (bit-exactness of the pooled serving
//!   path falls out of this). [`BufferPool::lease_dirty`] skips the
//!   zero-fill for buffers the caller fully overwrites before reading —
//!   the steady-state hot path's dominant case.
//! * **Bounded retention** — `give_back` drops slabs beyond
//!   `max_retained` (the shrink path), and [`BufferPool::disabled`]
//!   retains nothing, which turns every lease into a plain allocation —
//!   the "unpooled" baseline the serve bench compares against.
//! * **Shared, cheaply lockable** — one mutex around the free list; the
//!   serving layer uses one pool per worker lane (prep thread + device
//!   thread), so contention is two threads at batch granularity.

use std::sync::Mutex;

use super::tensor_buf::TensorBuf;

/// Cumulative pool counters (monotonic except `retained*`, which track
/// the current free list).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Leases served from the free list.
    pub hits: u64,
    /// Leases that had to allocate.
    pub misses: u64,
    /// Total bytes handed out across all leases (hit or miss).
    pub bytes_leased: u64,
    /// Slabs currently retained on the free list.
    pub retained: usize,
    /// Capacity bytes currently retained on the free list.
    pub retained_bytes: usize,
}

impl PoolStats {
    /// Fraction of leases served without allocating (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Merge another pool's counters into this one (per-worker pools are
    /// aggregated into one `ServeMetrics` view).
    pub fn absorb(&mut self, o: &PoolStats) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.bytes_leased += o.bytes_leased;
        self.retained += o.retained;
        self.retained_bytes += o.retained_bytes;
    }
}

#[derive(Debug, Default)]
struct PoolInner {
    /// Returned slabs, kept exactly as given back — length and contents
    /// retained. `lease` clears/zero-fills on the way OUT, and
    /// `lease_dirty` relies on the retained length to skip that fill,
    /// so give_back must NOT clear.
    free: Vec<Vec<f32>>,
    hits: u64,
    misses: u64,
    bytes_leased: u64,
}

/// A recycling pool of `Vec<f32>` slabs (see module docs).
#[derive(Debug)]
pub struct BufferPool {
    inner: Mutex<PoolInner>,
    max_retained: usize,
}

impl BufferPool {
    /// Pool with the default retention bound (64 slabs — several times a
    /// worker lane's steady-state working set).
    pub fn new() -> Self {
        Self::with_max_retained(64)
    }

    /// Pool retaining at most `max_retained` free slabs; returns beyond
    /// that are dropped (the shrink path).
    pub fn with_max_retained(max_retained: usize) -> Self {
        Self {
            inner: Mutex::new(PoolInner::default()),
            max_retained,
        }
    }

    /// Pool that retains nothing: every lease allocates, every return
    /// frees. This is the "unpooled" allocating baseline — same call
    /// sites, pure allocator behaviour.
    pub fn disabled() -> Self {
        Self::with_max_retained(0)
    }

    /// Pop the smallest retained slab whose capacity covers `len`
    /// (recording a hit), or record a miss. Only this pop happens under
    /// the pool mutex — any zero-fill or miss-path allocation runs
    /// outside it, so one lane thread memsetting a large noise slab
    /// never blocks the other's lease/return.
    fn pop_best_fit(&self, len: usize) -> Option<Vec<f32>> {
        let mut inner = self.inner.lock().unwrap();
        inner.bytes_leased += (len * std::mem::size_of::<f32>()) as u64;
        let mut best: Option<(usize, usize)> = None;
        for (i, s) in inner.free.iter().enumerate() {
            let cap = s.capacity();
            let better = match best {
                None => true,
                Some((_, best_cap)) => cap < best_cap,
            };
            if cap >= len && better {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                inner.hits += 1;
                Some(inner.free.swap_remove(i))
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Lease a zeroed slab of exactly `len` elements. Served from the
    /// free list when a retained slab's capacity covers `len` (best
    /// fit); otherwise allocates.
    pub fn lease(&self, len: usize) -> Vec<f32> {
        match self.pop_best_fit(len) {
            Some(mut v) => {
                // returned slabs keep their old contents: clear, then
                // fill the working range so a recycled slab is
                // indistinguishable from a fresh `vec![0.0; len]`
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Lease a slab of exactly `len` elements with UNSPECIFIED contents
    /// (recycled data may be visible) — the no-memset variant for
    /// buffers the caller fully overwrites before reading (stacked
    /// images, embeddings, noise draws, chunk scratch). Anything not
    /// provably written end to end must use [`BufferPool::lease`]
    /// instead.
    pub fn lease_dirty(&self, len: usize) -> Vec<f32> {
        match self.pop_best_fit(len) {
            Some(mut v) => {
                if v.len() > len {
                    v.truncate(len);
                } else {
                    // only the tail beyond the recycled length is filled
                    v.resize(len, 0.0);
                }
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Return a slab for reuse. Capacity (and, until the next lease,
    /// contents) are retained unless the free list is full; a zeroed
    /// lease clears the contents, a dirty lease may observe them.
    pub fn give_back(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.free.len() < self.max_retained {
            inner.free.push(v);
        }
        // else: drop — bounded retention IS the shrink behaviour
    }

    /// Drop retained slabs down to `keep`, preferring to keep the
    /// largest (most reusable) capacities.
    pub fn shrink(&self, keep: usize) {
        let mut inner = self.inner.lock().unwrap();
        if inner.free.len() > keep {
            inner.free.sort_by_key(|s| std::cmp::Reverse(s.capacity()));
            inner.free.truncate(keep);
        }
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.lock().unwrap();
        PoolStats {
            hits: inner.hits,
            misses: inner.misses,
            bytes_leased: inner.bytes_leased,
            retained: inner.free.len(),
            retained_bytes: inner
                .free
                .iter()
                .map(|s| s.capacity() * std::mem::size_of::<f32>())
                .sum(),
        }
    }

    /// Lease a zeroed tensor of the given shape (pool-backed
    /// [`TensorBuf`] construction).
    pub fn lease_tensor(&self, shape: &[usize]) -> TensorBuf {
        let n = shape.iter().product();
        TensorBuf {
            shape: shape.to_vec(),
            data: self.lease(n),
        }
    }

    /// Lease a tensor with unspecified contents (see
    /// [`BufferPool::lease_dirty`]) — for dispatch destinations and
    /// gather scratch that the callee fully overwrites.
    pub fn lease_tensor_dirty(&self, shape: &[usize]) -> TensorBuf {
        let n = shape.iter().product();
        TensorBuf {
            shape: shape.to_vec(),
            data: self.lease_dirty(n),
        }
    }

    /// Return a tensor's backing slab to the pool (the shape vec is
    /// dropped; only the data slab recycles).
    pub fn reclaim(&self, t: TensorBuf) {
        self.give_back(t.data);
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_miss_then_hit_on_return() {
        let p = BufferPool::new();
        let a = p.lease(16);
        assert_eq!(a.len(), 16);
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (0, 1));
        p.give_back(a);
        assert_eq!(p.stats().retained, 1);
        let b = p.lease(16);
        assert_eq!(b.len(), 16);
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_leased, 2 * 16 * 4);
    }

    #[test]
    fn recycled_leases_come_back_zeroed() {
        let p = BufferPool::new();
        let mut a = p.lease(8);
        a.iter_mut().for_each(|v| *v = 3.25);
        p.give_back(a);
        let b = p.lease(8);
        assert!(b.iter().all(|&v| v == 0.0), "recycled slab must be zeroed");
    }

    #[test]
    fn smaller_lease_reuses_bigger_slab() {
        let p = BufferPool::new();
        p.give_back(p.lease(100));
        let v = p.lease(40);
        assert_eq!(v.len(), 40);
        assert!(v.capacity() >= 100, "best fit reuses the retained slab");
        assert_eq!(p.stats().hits, 1);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_capacity() {
        let p = BufferPool::new();
        let big = p.lease(1000);
        let small = p.lease(50);
        p.give_back(big);
        p.give_back(small);
        let v = p.lease(30);
        assert!(
            v.capacity() < 1000,
            "a 30-element lease must take the 50-capacity slab, not the 1000"
        );
    }

    #[test]
    fn outstanding_leases_never_alias() {
        let p = BufferPool::new();
        p.give_back(p.lease(32));
        let a = p.lease(32);
        let b = p.lease(32);
        assert_ne!(
            a.as_ptr(),
            b.as_ptr(),
            "two outstanding leases must be distinct buffers"
        );
        // and both are independently writable end to end
        let mut a = a;
        let mut b = b;
        a.iter_mut().for_each(|v| *v = 1.0);
        b.iter_mut().for_each(|v| *v = 2.0);
        assert!(a.iter().all(|&v| v == 1.0));
        assert!(b.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn dirty_lease_skips_zeroing_but_sizes_correctly() {
        let p = BufferPool::new();
        let mut a = p.lease(8);
        a.iter_mut().for_each(|v| *v = 3.5);
        p.give_back(a);
        // a dirty lease may expose old contents, but must size exactly
        let d = p.lease_dirty(6);
        assert_eq!(d.len(), 6);
        assert_eq!(p.stats().hits, 1);
        p.give_back(d);
        // growing within capacity also sizes exactly
        let d2 = p.lease_dirty(8);
        assert_eq!(d2.len(), 8);
        // and a zeroed lease stays fully zeroed even after dirty traffic
        p.give_back(d2);
        let z = p.lease(8);
        assert!(z.iter().all(|&v| v == 0.0), "zeroed lease after dirty reuse");
        // dirty tensor leases keep the shape/len invariant
        p.give_back(z);
        let t = p.lease_tensor_dirty(&[2, 4]);
        assert_eq!(t.shape, vec![2, 4]);
        assert_eq!(t.len(), 8);
    }

    #[test]
    fn retention_bound_drops_excess_returns() {
        let p = BufferPool::with_max_retained(2);
        let slabs: Vec<_> = (0..4).map(|_| p.lease(8)).collect();
        for s in slabs {
            p.give_back(s);
        }
        assert_eq!(p.stats().retained, 2, "returns beyond the bound are dropped");
    }

    #[test]
    fn shrink_keeps_largest_slabs() {
        let p = BufferPool::new();
        p.give_back(p.lease(10));
        p.give_back(p.lease(1000));
        p.give_back(p.lease(100));
        p.shrink(1);
        let s = p.stats();
        assert_eq!(s.retained, 1);
        assert!(
            s.retained_bytes >= 1000 * 4,
            "shrink keeps the most reusable (largest) slab"
        );
    }

    #[test]
    fn shrink_edge_paths() {
        // keep >= retained: a no-op, nothing dropped
        let p = BufferPool::new();
        p.give_back(p.lease(8));
        p.give_back(p.lease(16));
        p.shrink(5);
        assert_eq!(p.stats().retained, 2, "shrink above the count is a no-op");
        p.shrink(2);
        assert_eq!(p.stats().retained, 2, "shrink at the count is a no-op");
        // shrink(0) empties the free list entirely
        p.shrink(0);
        let s = p.stats();
        assert_eq!(s.retained, 0);
        assert_eq!(s.retained_bytes, 0);
        // and the pool still works afterwards (leases just allocate)
        let v = p.lease(8);
        assert_eq!(v.len(), 8);
        // shrink of an empty pool is safe
        p.shrink(0);
        assert_eq!(p.stats().retained, 0);
    }

    #[test]
    fn retain_bound_exact_boundary_and_refill() {
        // returns land exactly at the bound, never beyond — and a lease
        // out of the bounded list re-opens a slot for the next return
        let p = BufferPool::with_max_retained(2);
        p.give_back(p.lease(8));
        p.give_back(p.lease(8));
        assert_eq!(p.stats().retained, 2, "filled exactly to the bound");
        p.give_back(p.lease(8)); // lease takes one out, return puts it back
        assert_eq!(p.stats().retained, 2, "stays at the bound across churn");
        let held = p.lease(8);
        assert_eq!(p.stats().retained, 1, "outstanding lease frees a slot");
        p.give_back(held);
        assert_eq!(p.stats().retained, 2);
        // with_max_retained(0) behaves exactly like disabled()
        let z = BufferPool::with_max_retained(0);
        z.give_back(z.lease(4));
        assert_eq!(z.stats().retained, 0);
        assert_eq!(z.stats().hits, 0);
    }

    #[test]
    fn disabled_pool_always_allocates() {
        let p = BufferPool::disabled();
        p.give_back(p.lease(8));
        p.give_back(p.lease(8));
        let s = p.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 2);
        assert_eq!(s.retained, 0);
    }

    #[test]
    fn tensor_lease_and_reclaim_roundtrip() {
        let p = BufferPool::new();
        let t = p.lease_tensor(&[2, 3]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data.iter().all(|&v| v == 0.0));
        p.reclaim(t);
        assert_eq!(p.stats().retained, 1);
        let t2 = p.lease_tensor(&[6]);
        assert_eq!(p.stats().hits, 1);
        assert_eq!(t2.len(), 6);
    }

    #[test]
    fn zero_len_lease_is_safe() {
        let p = BufferPool::new();
        let v = p.lease(0);
        assert!(v.is_empty());
        p.give_back(v); // capacity 0: silently dropped
        assert_eq!(p.stats().retained, 0);
    }

    #[test]
    fn stats_hit_rate_and_absorb() {
        let mut a = PoolStats {
            hits: 3,
            misses: 1,
            bytes_leased: 100,
            retained: 2,
            retained_bytes: 64,
        };
        assert!((a.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(PoolStats::default().hit_rate(), 0.0);
        let b = PoolStats {
            hits: 1,
            misses: 1,
            bytes_leased: 50,
            retained: 1,
            retained_bytes: 32,
        };
        a.absorb(&b);
        assert_eq!(a.hits, 4);
        assert_eq!(a.misses, 2);
        assert_eq!(a.bytes_leased, 150);
        assert_eq!(a.retained, 3);
    }
}
