//! CARLA [15]-like row-stationary baseline.
//!
//! CARLA ("a convolution accelerator with a reconfigurable and low-energy
//! architecture", TCAS-I 2021) computes convolutions row-by-row with
//! partial-sum precomputation. The SF-MMCN paper characterizes it as:
//!
//! * first convolution output after `3N` cycles for an `N`-pixel row
//!   (Fig 22), i.e. `k * N` for the general `k x k` filter;
//! * one convolution output per `k` cycles in steady state ("CARLA only
//!   provides one convolution output in the same cycle", Fig 23);
//! * ~3 PEs executing MACs per cycle when the filter is 3x3 ("The P_act
//!   only 3 when the size of the filter is 3x3", §IV.C) out of 196 PEs.
//!
//! We implement exactly this characterization — it is what Table II and
//! Figs 22-23 are drawn from — and label it `carla-paper`. Published
//! datasheet numbers for the real CARLA live in [`super::published`].

use crate::models::graph::{Layer, ModelGraph};
use crate::sim::energy::EventCounts;

use super::BaselineRun;

/// PEs in the CARLA organisation (Table I: 196, in 65 columns).
pub const CARLA_PES: u64 = 196;
/// Column count (its organisational "units" for the area model).
pub const CARLA_COLUMNS: u64 = 65;

/// Cycles until the first conv output of an `n`-pixel row (Fig 22).
pub fn first_output_cycles(n: u64, k: u64) -> u64 {
    k * n
}

/// Cycles for one convolution output in steady state (Fig 23).
pub fn cycles_per_output(k: u64) -> u64 {
    k
}

/// Active MAC lanes per cycle for a `k x k` filter (paper §IV.C).
pub fn active_pes(k: u64) -> u64 {
    k
}

/// Analytic event counts for a whole graph on the CARLA-like machine.
///
/// Convs: `k` cycles per output per input channel, `k` PEs firing.
/// Pool/dense/reshape ops are charged like the SF model's peripheral
/// lanes (they are not what the comparison is about).
pub fn analyze_graph(g: &ModelGraph) -> BaselineRun {
    let mut c = EventCounts {
        total_pes: CARLA_PES,
        // traditional array: no fine-grained clock gating of idle PEs
        coarse_idle: true,
        ..Default::default()
    };
    for node in &g.nodes {
        match &node.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                residual,
                time_dense,
                ..
            } => {
                let outputs =
                    (node.out_shape.h * node.out_shape.w * c_out) as u64 * *c_in as u64;
                let k = *k as u64;
                let cycles = outputs * cycles_per_output(k);
                let macs = outputs * k * k;
                c.cycles += cycles;
                c.pe.macs += macs;
                c.pe.active_cycles += macs; // k PEs x k*N cycles per row
                c.pe.writebacks += outputs;
                // No SF server: parallel branches are extra passes.
                match residual {
                    crate::models::graph::Residual::None => {}
                    crate::models::graph::Residual::Identity { .. } => {
                        let elems = node.out_shape.elems();
                        c.cycles += elems.div_ceil(active_pes(k));
                        c.mem.output_buf_reads += elems;
                        c.pe.residual_adds += elems;
                    }
                    crate::models::graph::Residual::Conv { from, .. } => {
                        let cs = g.nodes[*from].out_shape.c as u64;
                        let outs = node.out_shape.elems();
                        let rmacs = outs * cs;
                        c.cycles += rmacs * cycles_per_output(1);
                        c.pe.macs += rmacs;
                        c.pe.active_cycles += rmacs;
                        c.pe.residual_adds += outs;
                        c.mem.output_buf_reads += outs * cs;
                    }
                }
                if let Some(td) = time_dense {
                    let dmacs = (*td * c_out) as u64;
                    c.cycles += dmacs; // serial dense pass
                    c.pe.macs += dmacs;
                    c.pe.active_cycles += dmacs;
                }
                // memory: no reuse registers -> every tap is a buffer read
                let reads = macs;
                c.unit.buffer_reads += reads;
                c.unit.buffer_reads_no_reuse += reads;
                c.unit.weight_reads += macs;
                c.mem.dram_reads += node.in_shape.elems()
                    + (*c_out * *c_in * node_k(node)) as u64;
                c.mem.input_buf_writes += node.in_shape.elems();
                c.mem.output_buf_writes += node.out_shape.elems();
            }
            Layer::Dense { in_f, out_f, .. } => {
                let macs = (*in_f * *out_f) as u64;
                c.cycles += macs / active_pes(3).max(1);
                c.pe.macs += macs;
                c.pe.active_cycles += macs;
                c.unit.buffer_reads += macs;
                c.unit.buffer_reads_no_reuse += macs;
                c.mem.dram_reads += macs + *in_f as u64;
                c.mem.output_buf_writes += *out_f as u64;
            }
            _ => {
                // pools / reshapes: peripheral, one element per cycle lane
                let elems = node.out_shape.elems();
                c.cycles += elems.div_ceil(8);
                c.mem.input_buf_reads += node.in_shape.elems();
                c.mem.output_buf_writes += elems;
            }
        }
    }
    BaselineRun {
        name: "carla-paper",
        counts: c,
        units: CARLA_COLUMNS,
    }
}

fn node_k(node: &crate::models::graph::Node) -> usize {
    match &node.layer {
        Layer::Conv { k, .. } => k * k,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet18, vgg16};
    use crate::sim::array::AcceleratorConfig;

    #[test]
    fn paper_characterization_numbers() {
        // Table II: pixel 28 -> 84 cycles/CONV; Fig 22 first-output = 3N
        assert_eq!(first_output_cycles(28, 3), 84);
        assert_eq!(first_output_cycles(32, 3), 96);
        assert_eq!(first_output_cycles(224, 3), 672);
        assert_eq!(cycles_per_output(3), 3);
        assert_eq!(active_pes(3), 3);
    }

    #[test]
    fn carla_much_slower_than_sf_on_vgg() {
        let g = vgg16(32, 10);
        let carla = analyze_graph(&g);
        let sf = crate::compiler::analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
        assert!(
            carla.counts.cycles > 5 * sf.total_cycles(),
            "carla {} vs sf {}",
            carla.counts.cycles,
            sf.total_cycles()
        );
    }

    #[test]
    fn carla_utilization_tiny() {
        let g = resnet18(32, 10);
        let carla = analyze_graph(&g);
        // 3-ish active of 196 -> a couple percent
        assert!(carla.counts.u_pe() < 0.05, "u_pe = {}", carla.counts.u_pe());
    }

    #[test]
    fn residual_adds_extra_cycles_on_carla() {
        use crate::models::graph::{Act, GraphBuilder, Layer as L, Residual, TensorShape};
        let mk = |residual| {
            let mut b = GraphBuilder::new("t", TensorShape::new(8, 8, 8));
            b.add(L::Conv {
                c_in: 8,
                c_out: 8,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual: Residual::None,
                time_dense: None,
            })
            .unwrap();
            b.add(L::Conv {
                c_in: 8,
                c_out: 8,
                k: 3,
                stride: 1,
                pad: 1,
                act: Act::None,
                residual,
                time_dense: None,
            })
            .unwrap();
            b.build()
        };
        let plain = analyze_graph(&mk(crate::models::graph::Residual::None));
        let res = analyze_graph(&mk(crate::models::graph::Residual::Identity { from: 0 }));
        assert!(
            res.counts.cycles > plain.counts.cycles,
            "the series strategy must pay extra cycles for the skip"
        );
    }
}
