//! Baseline accelerators the paper compares against, modelled under the
//! *same* event-energy framework as SF-MMCN so the Table-I ratios are
//! apples-to-apples (see DESIGN.md §1 on why ratios survive the
//! silicon→simulation substitution).
//!
//! * [`carla`] — CARLA [15]-like row-stationary array, using the *paper's
//!   own characterization* of CARLA's dataflow (Table II, Figs 22-23).
//! * [`mmcn`] — the authors' previous MMCN [24]: same MAC core idea but a
//!   series strategy for parallel structures and no data-reuse registers.
//! * [`pe_array`] — a traditional parallel PE array: executes residual
//!   branches concurrently on extra silicon (the "parallel strategy").
//! * [`published`] — the as-published Table-I rows for accelerators we do
//!   not simulate ([19], [28], [29], [30]).

pub mod carla;
pub mod mmcn;
pub mod pe_array;
pub mod published;

use crate::sim::energy::EventCounts;

/// A named simulated baseline run, ready for PPA pricing.
#[derive(Debug, Clone)]
pub struct BaselineRun {
    pub name: &'static str,
    pub counts: EventCounts,
    /// Organisational unit count (for the area model).
    pub units: u64,
}
