//! MMCN [24] — the authors' previous-generation accelerator and the
//! paper's own ablation baseline (Fig 24).
//!
//! Differences from SF-MMCN, per §II:
//! 1. **Series strategy for parallel structures**: a residual block is
//!    serialized — main conv pass, then the skip branch as its *own* pass
//!    (1x1 conv if present), then an element-wise add pass. Each extra
//!    pass also round-trips the feature map through memory.
//! 2. **No data-reuse registers**: every window tap is a buffer read, and
//!    big feature maps re-stream from DRAM per output-channel iteration.
//! 3. **32 PEs** (4 units x 8, no PE_9 servers).
//!
//! We reuse the SF schedule model on a *serialized* transform of the graph
//! (residual/time branches split into standalone nodes), with
//! `data_reuse = false` — so every formula is shared with the SF analysis
//! and the comparison isolates exactly the paper's two claims.

use crate::compiler::schedule::analyze_graph as analyze_sf;
use crate::models::graph::{Act, Layer, ModelGraph, Node, Residual, TensorShape};
use crate::sim::array::AcceleratorConfig;
use crate::sim::energy::EventCounts;
use crate::sim::unit::PES_PER_UNIT;

use super::BaselineRun;

/// MMCN organisation: 4 units x 8 PEs = 32 (Table I: 32 PEs).
pub const MMCN_UNITS: usize = 4;

/// The accelerator config MMCN maps to in the shared cost model.
/// `units = 4` but *without* PE_9: we account for that by pricing with
/// `total_pes = 32` (see [`analyze_graph`]).
pub fn config() -> AcceleratorConfig {
    AcceleratorConfig {
        units: MMCN_UNITS,
        data_reuse: false,
        ..AcceleratorConfig::default()
    }
}

/// Serialize parallel structures: every `Residual::*` conv becomes a plain
/// conv followed by (optional 1x1-conv node) + an add pass; `time_dense`
/// becomes a standalone dense node. Returns the transformed node list.
pub fn serialize_graph(g: &ModelGraph) -> ModelGraph {
    let mut nodes: Vec<Node> = Vec::new();
    for node in &g.nodes {
        match &node.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                stride,
                pad,
                act,
                residual,
                time_dense,
            } => {
                // 1) the main conv, stripped of its parallel branches
                nodes.push(Node {
                    layer: Layer::Conv {
                        c_in: *c_in,
                        c_out: *c_out,
                        k: *k,
                        stride: *stride,
                        pad: *pad,
                        act: *act,
                        residual: Residual::None,
                        time_dense: None,
                    },
                    in_shape: node.in_shape,
                    out_shape: node.out_shape,
                });
                // 2) the skip branch as its own pass
                match residual {
                    Residual::None => {}
                    Residual::Identity { .. } => {
                        nodes.push(eltwise_add_node(node.out_shape));
                    }
                    Residual::Conv { from, stride } => {
                        let src = g.nodes[*from].out_shape;
                        // standalone 1x1 conv over the skip source
                        nodes.push(Node {
                            layer: Layer::Conv {
                                c_in: src.c,
                                c_out: node.out_shape.c,
                                k: 1,
                                stride: *stride,
                                pad: 0,
                                act: Act::None,
                                residual: Residual::None,
                                time_dense: None,
                            },
                            in_shape: src,
                            out_shape: node.out_shape,
                        });
                        nodes.push(eltwise_add_node(node.out_shape));
                    }
                }
                // 3) the time-parameter dense as its own pass
                if let Some(td) = time_dense {
                    nodes.push(Node {
                        layer: Layer::Dense {
                            in_f: *td,
                            out_f: node.out_shape.c,
                            act: Act::None,
                        },
                        in_shape: TensorShape::new(*td, 1, 1),
                        out_shape: TensorShape::new(node.out_shape.c, 1, 1),
                    });
                    // broadcasting the bias over the map is another pass
                    nodes.push(eltwise_add_node(node.out_shape));
                }
            }
            other => nodes.push(Node {
                layer: other.clone(),
                in_shape: node.in_shape,
                out_shape: node.out_shape,
            }),
        }
    }
    ModelGraph {
        name: format!("{}-serialized", g.name),
        input: g.input,
        nodes,
    }
}

/// An element-wise add pass is modelled as a 1x1 "conv" with one input
/// channel tap — one MAC per element through the shared MAC core, plus
/// the memory round-trip of the second operand.
fn eltwise_add_node(shape: TensorShape) -> Node {
    Node {
        layer: Layer::Conv {
            c_in: 1,
            c_out: 1,
            k: 1,
            stride: 1,
            pad: 0,
            act: Act::None,
            residual: Residual::None,
            time_dense: None,
        },
        in_shape: TensorShape::new(1, shape.c * shape.h, shape.w),
        out_shape: TensorShape::new(1, shape.c * shape.h, shape.w),
    }
}

/// Buffer port width per unit, elements/cycle. SF-MMCN's reuse registers
/// keep its demand at ~3.3 reads/cycle/unit (30 distinct values per
/// 9-cycle group), inside this port. MMCN has no reuse registers, so its
/// 8 lanes demand 8 reads/cycle — the fetch phase cannot hide under
/// compute and the core stalls (§II: "data transmission between core and
/// memories has the most power"; it also has the cycles).
pub const BUFFER_PORT_PER_UNIT: u64 = 4;

/// Analytic event counts for a graph on MMCN.
pub fn analyze_graph(g: &ModelGraph, sparsity: f64) -> BaselineRun {
    let serialized = serialize_graph(g);
    let cfg = config();
    let a = analyze_sf(&cfg, &serialized, sparsity);
    let mut counts: EventCounts = a.totals;
    // MMCN has no PE_9 servers: 32 PEs total instead of 4 x 9. The
    // schedule model never used the servers on the serialized graph, so
    // only the idle-pricing denominator changes.
    counts.total_pes = (MMCN_UNITS * (PES_PER_UNIT - 1)) as u64;
    // Fetch stalls: without reuse registers every window tap streams
    // through the buffer port, serialized after compute (no double
    // buffering). The stall cycles idle the whole MAC array.
    let fetch_cycles =
        counts.unit.buffer_reads / (BUFFER_PORT_PER_UNIT * MMCN_UNITS as u64);
    counts.cycles += fetch_cycles;
    // Serialization costs an extra DRAM round-trip of the skip per branch
    // (the paper's "large memory usage ... in parallel CNN structure").
    let mut extra_dram = 0u64;
    for node in &g.nodes {
        if let Layer::Conv { residual, .. } = &node.layer {
            if !matches!(residual, Residual::None) {
                extra_dram += 2 * node.out_shape.elems(); // spill + reload
            }
        }
    }
    counts.mem.dram_writes += extra_dram / 2;
    counts.mem.dram_reads += extra_dram / 2;
    BaselineRun {
        name: "mmcn",
        counts,
        units: MMCN_UNITS as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet18, unet, vgg16, UnetConfig};

    #[test]
    fn serialization_preserves_series_graphs() {
        let g = vgg16(32, 10);
        let s = serialize_graph(&g);
        assert_eq!(s.nodes.len(), g.nodes.len(), "VGG has no parallel nodes");
    }

    #[test]
    fn serialization_expands_parallel_graphs() {
        let g = resnet18(32, 10);
        let s = serialize_graph(&g);
        // 5 identity blocks -> +1 node each; 3 downsample -> +2 each
        assert_eq!(s.nodes.len(), g.nodes.len() + 5 + 6);
    }

    #[test]
    fn mmcn_slower_than_sf_on_parallel_models() {
        let g = resnet18(32, 10);
        let mm = analyze_graph(&g, 0.0);
        let sf =
            crate::compiler::analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
        // fewer units AND extra serialized passes
        assert!(
            mm.counts.cycles > sf.total_cycles() * 2,
            "mmcn {} vs sf {}",
            mm.counts.cycles,
            sf.total_cycles()
        );
    }

    #[test]
    fn mmcn_gap_larger_on_unet_than_vgg() {
        // Fig 24's point: the latency gap explodes on parallel models.
        let vgg = vgg16(32, 10);
        let un = unet(UnetConfig::default());
        let cfg = AcceleratorConfig::default();
        let r = |g: &ModelGraph| {
            let mm = analyze_graph(g, 0.0).counts.cycles as f64;
            let sf = crate::compiler::analyze_graph(&cfg, g, 0.0).total_cycles() as f64;
            mm / sf
        };
        let gap_vgg = r(&vgg);
        let gap_unet = r(&un);
        assert!(
            gap_unet > gap_vgg,
            "unet gap {gap_unet:.2} should exceed vgg gap {gap_vgg:.2}"
        );
    }

    #[test]
    fn no_reuse_means_more_buffer_reads() {
        // conv-only graph: MMCN (no reuse registers) must read every tap.
        // (Dense layers share the broadcast input structurally on both
        // machines, so they are excluded here.)
        use crate::models::graph::{Act, GraphBuilder, Layer as L, TensorShape};
        let mut b = GraphBuilder::new("t", TensorShape::new(8, 16, 16));
        b.add(L::Conv {
            c_in: 8,
            c_out: 16,
            k: 3,
            stride: 1,
            pad: 1,
            act: Act::Relu,
            residual: Residual::None,
            time_dense: None,
        })
        .unwrap();
        let g = b.build();
        let mm = analyze_graph(&g, 0.0);
        assert_eq!(
            mm.counts.unit.buffer_reads, mm.counts.unit.buffer_reads_no_reuse,
            "MMCN reads every conv tap"
        );
        // and strictly more than SF with reuse on the same graph
        let sf = crate::compiler::analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
        assert!(mm.counts.unit.buffer_reads > sf.totals.unit.buffer_reads);
    }

    #[test]
    fn parallel_branches_cost_dram_on_mmcn() {
        let g = resnet18(32, 10);
        let mm = analyze_graph(&g, 0.0);
        let sf = crate::compiler::analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
        assert!(mm.counts.mem.dram_traffic() > sf.totals.mem.dram_traffic());
    }
}
