//! Traditional parallel PE array — the "parallel strategy" strawman of
//! §I: executes parallel branches *concurrently on extra silicon*. Good
//! latency, but the branch hardware idles on series layers, which is
//! exactly the redundancy the efficiency factor nu exposes.
//!
//! Organisation: a 16x16 output-stationary MAC array (256 PEs) plus a
//! dedicated 64-PE branch array (residual / time path) — 320 PEs total.

use crate::models::graph::{Layer, ModelGraph, Residual};
use crate::sim::energy::EventCounts;

use super::BaselineRun;

/// Main-array MAC lanes.
pub const MAIN_PES: u64 = 256;
/// Dedicated parallel-branch lanes.
pub const BRANCH_PES: u64 = 64;
/// Total PEs in the design.
pub const TOTAL_PES: u64 = MAIN_PES + BRANCH_PES;

/// Analytic event counts for a graph on the parallel PE array.
pub fn analyze_graph(g: &ModelGraph) -> BaselineRun {
    let mut c = EventCounts {
        total_pes: TOTAL_PES,
        // dense array without the SF mode/zero gating of idle lanes
        coarse_idle: true,
        ..Default::default()
    };
    for node in &g.nodes {
        match &node.layer {
            Layer::Conv {
                c_in,
                c_out,
                k,
                residual,
                time_dense,
                ..
            } => {
                let macs =
                    node.out_shape.elems() * (*k * *k * *c_in) as u64;
                // output-stationary: engage min(256, 8 * c_out) lanes
                let engaged = MAIN_PES.min(8 * *c_out as u64).max(1);
                let cycles = macs.div_ceil(engaged);
                c.cycles += cycles;
                c.pe.macs += macs;
                c.pe.active_cycles += macs;
                c.pe.writebacks += node.out_shape.elems();
                // branch array runs *concurrently* -> no extra cycles
                match residual {
                    Residual::None => {}
                    Residual::Identity { .. } => {
                        let elems = node.out_shape.elems();
                        c.pe.residual_adds += elems;
                        c.pe.active_cycles += elems; // branch lanes
                        c.mem.output_buf_reads += elems;
                    }
                    Residual::Conv { from, .. } => {
                        let cs = g.nodes[*from].out_shape.c as u64;
                        let rmacs = node.out_shape.elems() * cs;
                        c.pe.macs += rmacs;
                        c.pe.active_cycles += rmacs;
                        c.pe.residual_adds += node.out_shape.elems();
                        c.mem.output_buf_reads += node.out_shape.elems() * cs;
                        // branch may be slower than the main conv tile:
                        let branch_cycles = rmacs.div_ceil(BRANCH_PES);
                        if branch_cycles > cycles {
                            c.cycles += branch_cycles - cycles;
                        }
                    }
                }
                if let Some(td) = time_dense {
                    let dmacs = (*td * node.out_shape.c) as u64;
                    c.pe.macs += dmacs;
                    c.pe.active_cycles += dmacs;
                }
                // modest reuse (systolic forwarding): half the taps re-read
                let reads = macs / 2;
                c.unit.buffer_reads += reads;
                c.unit.buffer_reads_no_reuse += macs;
                c.unit.reuse_reg_writes += macs - reads;
                c.unit.weight_reads += (*k * *k * *c_in * *c_out) as u64;
                c.mem.dram_reads +=
                    node.in_shape.elems() + (*c_out * *c_in * *k * *k) as u64;
                c.mem.input_buf_writes += node.in_shape.elems();
                c.mem.output_buf_writes += node.out_shape.elems();
            }
            Layer::Dense { in_f, out_f, .. } => {
                let macs = (*in_f * *out_f) as u64;
                c.cycles += macs.div_ceil(MAIN_PES);
                c.pe.macs += macs;
                c.pe.active_cycles += macs;
                c.unit.buffer_reads += macs / 2;
                c.unit.buffer_reads_no_reuse += macs;
                c.mem.dram_reads += macs + *in_f as u64;
                c.mem.output_buf_writes += *out_f as u64;
            }
            _ => {
                let elems = node.out_shape.elems();
                c.cycles += elems.div_ceil(64);
                c.mem.input_buf_reads += node.in_shape.elems();
                c.mem.output_buf_writes += elems;
            }
        }
    }
    BaselineRun {
        name: "pe-array",
        counts: c,
        units: 16, // 16 rows as organisational units
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet18, vgg16};
    use crate::sim::array::AcceleratorConfig;
    use crate::sim::energy::CAL_40NM;

    #[test]
    fn fast_but_inefficient() {
        let g = resnet18(32, 10);
        let pa = analyze_graph(&g);
        let sf = crate::compiler::analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
        // more PEs -> fewer cycles...
        assert!(pa.counts.cycles < sf.total_cycles());
        // ...but worse efficiency factor (nu): idle branch silicon
        let rep_pa = CAL_40NM.report(&pa.counts, pa.units);
        let rep_sf = CAL_40NM.report(&sf.totals, 8);
        assert!(
            rep_pa.nu > rep_sf.nu,
            "pe-array nu {} must exceed SF nu {}",
            rep_pa.nu,
            rep_sf.nu
        );
    }

    #[test]
    fn branch_array_idles_on_series_models() {
        let g = vgg16(32, 10);
        let pa = analyze_graph(&g);
        // utilization includes the idle 64-lane branch array
        assert!(
            pa.counts.u_pe() < 0.85,
            "u_pe = {} should reflect idle branch lanes",
            pa.counts.u_pe()
        );
    }

    #[test]
    fn area_larger_than_sf() {
        let pa_area = CAL_40NM.area_mm2(TOTAL_PES, 16);
        let sf_area = CAL_40NM.area_mm2(72, 8);
        assert!(pa_area > 2.0 * sf_area);
    }
}
