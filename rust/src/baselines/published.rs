//! As-published Table-I rows for the accelerators we do not simulate.
//! The paper itself quotes these from the cited references; we do the
//! same so the regenerated Table I carries every column.

/// One row of Table I.
#[derive(Debug, Clone)]
pub struct PublishedRow {
    pub name: &'static str,
    pub reference: &'static str,
    pub freq_mhz: &'static str,
    pub tech: &'static str,
    pub area_mm2: Option<f64>,
    pub gate_count: Option<&'static str>,
    pub precision_bits: &'static str,
    pub num_pes: Option<u64>,
    pub models: &'static str,
    pub power_mw: &'static str,
    pub throughput_gops: &'static str,
    pub energy_eff_gops_w: &'static str,
    pub area_eff_gops_mm2: Option<f64>,
    pub nu: Option<f64>,
}

/// Every non-simulated row of Table I, as printed in the paper.
pub fn table1_rows() -> Vec<PublishedRow> {
    vec![
        PublishedRow {
            name: "CARLA",
            reference: "TCASI'21 [15]",
            freq_mhz: "200",
            tech: "65nm",
            area_mm2: Some(6.2),
            gate_count: Some("938k"),
            precision_bits: "16",
            num_pes: Some(196),
            models: "VGG-16 / ResNet-50",
            power_mw: "247",
            throughput_gops: "77.4 / 75.4",
            energy_eff_gops_w: "0.31k / 0.3k",
            area_eff_gops_mm2: Some(12.48),
            nu: Some(82.3),
        },
        PublishedRow {
            name: "IECA",
            reference: "TCASI'21 [28]",
            freq_mhz: "250",
            tech: "55nm",
            area_mm2: Some(2.75),
            gate_count: None,
            precision_bits: "16",
            num_pes: Some(168),
            models: "VGG-16 / AlexNet",
            power_mw: "114.6",
            throughput_gops: "84.0",
            energy_eff_gops_w: "n/a",
            area_eff_gops_mm2: Some(30.55),
            nu: None,
        },
        PublishedRow {
            name: "Interlayer-compress",
            reference: "TCASI'22 [29]",
            freq_mhz: "700",
            tech: "28nm",
            area_mm2: None,
            gate_count: Some("1.12M"),
            precision_bits: "16",
            num_pes: Some(288),
            models: "VGG-16",
            power_mw: "186.6",
            throughput_gops: "403",
            energy_eff_gops_w: "2.1k",
            area_eff_gops_mm2: None,
            nu: Some(0.64),
        },
        PublishedRow {
            name: "QNAP",
            reference: "ISSCC'21 [19]",
            freq_mhz: "100-470",
            tech: "28nm",
            area_mm2: Some(1.9),
            gate_count: None,
            precision_bits: "8",
            num_pes: Some(144),
            models: "AlexNet/VGG/GoogleNet/ResNet",
            power_mw: "19.4 - 131.6",
            throughput_gops: "n/a",
            energy_eff_gops_w: "12.1k",
            area_eff_gops_mm2: Some(745.1),
            nu: None,
        },
        PublishedRow {
            name: "Scalable-precision",
            reference: "ISSCC'23 [30]",
            freq_mhz: "20-400",
            tech: "28nm",
            area_mm2: Some(7.29),
            gate_count: None,
            precision_bits: "1-8",
            num_pes: Some(8),
            models: "Eff.N-L0 / ViT-T / M.Mxr-B",
            power_mw: "2.06-231.7",
            throughput_gops: "1870-18900",
            energy_eff_gops_w: "907k-551k",
            area_eff_gops_mm2: Some(2600.0),
            nu: None,
        },
        PublishedRow {
            name: "MMCN",
            reference: "MCSoC'23 [24]",
            freq_mhz: "200",
            tech: "90nm",
            area_mm2: Some(0.36),
            gate_count: None,
            precision_bits: "16",
            num_pes: Some(32),
            models: "VGG-16",
            power_mw: "3.58 (core)",
            throughput_gops: "2572.184 (different OP accounting)",
            energy_eff_gops_w: "718k",
            area_eff_gops_mm2: None,
            nu: Some(0.11),
        },
    ]
}

/// The paper's own "This work" row (the calibration target).
pub fn paper_this_work() -> PublishedRow {
    PublishedRow {
        name: "SF-MMCN (paper)",
        reference: "this work (paper)",
        freq_mhz: "400",
        tech: "40nm",
        area_mm2: Some(1.9),
        gate_count: Some("211k"),
        precision_bits: "16",
        num_pes: Some(72),
        models: "VGG-16 / ResNet-18",
        power_mw: "18",
        throughput_gops: "437.9",
        energy_eff_gops_w: "24.3k",
        area_eff_gops_mm2: Some(230.47),
        nu: Some(0.02),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_quoted_rows() {
        assert_eq!(table1_rows().len(), 6);
    }

    #[test]
    fn carla_row_matches_paper_ratios() {
        let rows = table1_rows();
        let carla = &rows[0];
        let this = paper_this_work();
        // headline claims: ~81x energy efficiency, ~18.42x area efficiency
        let eff_ratio = 24.3e3 / 0.3e3;
        assert!((80.0..82.0).contains(&eff_ratio));
        let area_ratio = this.area_eff_gops_mm2.unwrap() / carla.area_eff_gops_mm2.unwrap();
        assert!((18.0..19.0).contains(&area_ratio), "{area_ratio}");
    }

    #[test]
    fn nu_ratio_sf_vs_carla() {
        let carla_nu = table1_rows()[0].nu.unwrap();
        let sf_nu = paper_this_work().nu.unwrap();
        assert!(carla_nu / sf_nu > 4000.0);
    }
}
