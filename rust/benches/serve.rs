//! Bench: end-to-end denoise *serving* throughput (ISSUE 3).
//!
//! Runs the full coordinator path — queue → fair batcher → worker lanes —
//! on the native (host-CPU surrogate) backend, so it executes offline
//! with no artifacts and no PJRT. Four execution modes are measured:
//!
//! * `per_request`        — step-at-a-time, one dispatch per request-step
//!                          (the pre-ISSUE-3 serving loop; the baseline).
//! * `per_request_fused`  — one fused scan dispatch per request (§Perf L2).
//! * `batched_b4`         — cross-request batching: up to 4 requests per
//!                          `[B, ...]` dispatch, double-buffered host stage.
//! * `batched_b8`         — same with max_batch = 8.
//!
//! Run: `cargo bench --bench serve` (full) or `-- --quick` (CI profile).
//! Results go to `BENCH_serve.json`; with `--strict` the process exits 1
//! unless batched_b4 sustains >= 2x the per_request requests/sec — the
//! ISSUE 3 acceptance gate, enforced in CI.

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{DiffusionServer, ServeMetrics};
use sf_mmcn::runtime::ArtifactStore;

struct Row {
    name: String,
    requests: usize,
    steps: usize,
    wall_s: f64,
    req_per_s: f64,
    occupancy: f64,
    dispatches: usize,
    stalls: usize,
    speedup_vs_per_request: Option<f64>,
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn write_json(mode: &str, rows: &[Row]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", r.name));
        s.push_str(&format!("\"requests\": {}, ", r.requests));
        s.push_str(&format!("\"steps\": {}, ", r.steps));
        s.push_str(&format!("\"wall_s\": {}, ", json_f64(r.wall_s)));
        s.push_str(&format!("\"req_per_s\": {}, ", json_f64(r.req_per_s)));
        s.push_str(&format!(
            "\"batch_occupancy\": {}, ",
            json_f64(r.occupancy)
        ));
        s.push_str(&format!("\"dispatches\": {}, ", r.dispatches));
        s.push_str(&format!("\"pipeline_stalls\": {}", r.stalls));
        if let Some(sp) = r.speedup_vs_per_request {
            s.push_str(&format!(", \"speedup_vs_per_request\": {}", json_f64(sp)));
        }
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve.json", &s) {
        Ok(()) => println!("\nwrote BENCH_serve.json ({} results)", rows.len()),
        Err(e) => println!("\nWARNING: could not write BENCH_serve.json: {e}"),
    }
}

fn base_cfg(steps: usize, requests: usize) -> ServeConfig {
    ServeConfig {
        steps,
        requests,
        workers: 2,
        max_batch: 1,
        seed: 7,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        batched: false,
        pipeline: true,
        chunk: 0,
    }
}

/// Serve the workload once; panics on any serving error (this bench IS
/// the offline health check of the serving stack).
fn serve_once(cfg: &ServeConfig) -> ServeMetrics {
    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store).expect("native server");
    let reqs = server.workload(cfg.requests);
    let (results, metrics) = server.serve(reqs).expect("serve");
    assert_eq!(
        results.len(),
        cfg.requests,
        "every request must be answered exactly once"
    );
    metrics
}

/// Run a mode `iters` times and keep its best (max-throughput) session —
/// same best-of policy as wall-clock benchmarks use against noise.
fn measure(name: &str, cfg: &ServeConfig, iters: usize) -> Row {
    let mut best: Option<ServeMetrics> = None;
    for _ in 0..iters {
        let m = serve_once(cfg);
        let better = match &best {
            Some(b) => m.requests_per_s() > b.requests_per_s(),
            None => true,
        };
        if better {
            best = Some(m);
        }
    }
    let m = best.expect("at least one iteration");
    println!(
        "bench serve::{name:<20} {:>8.1} req/s  ({} req x {} steps, wall {:.3}s, \
         occupancy {:.2}, {} dispatches, {} stalls)",
        m.requests_per_s(),
        cfg.requests,
        cfg.steps,
        m.wall.as_secs_f64(),
        m.batch_occupancy(),
        m.dispatches,
        m.pipeline_stalls,
    );
    Row {
        name: name.to_string(),
        requests: cfg.requests,
        steps: cfg.steps,
        wall_s: m.wall.as_secs_f64(),
        req_per_s: m.requests_per_s(),
        occupancy: m.batch_occupancy(),
        dispatches: m.dispatches,
        stalls: m.pipeline_stalls,
        speedup_vs_per_request: None,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("SF_MMCN_BENCH_QUICK").is_ok();
    let strict = args.iter().any(|a| a == "--strict");
    let (steps, requests, iters) = if quick { (4, 16, 2) } else { (16, 48, 3) };
    println!(
        "==================== SERVE BENCH ({}) ====================\n\
         native surrogate backend, 2 workers, {requests} requests x {steps} steps\n",
        if quick { "quick" } else { "full" }
    );

    let mut rows = Vec::new();

    let per_request = measure("per_request", &base_cfg(steps, requests), iters);
    let base_rate = per_request.req_per_s;
    rows.push(per_request);

    let mut fused_cfg = base_cfg(steps, requests);
    fused_cfg.fused = true;
    rows.push(measure("per_request_fused", &fused_cfg, iters));

    let mut b4 = base_cfg(steps, requests);
    b4.batched = true;
    b4.max_batch = 4;
    rows.push(measure("batched_b4", &b4, iters));

    let mut b8 = base_cfg(steps, requests);
    b8.batched = true;
    b8.max_batch = 8;
    rows.push(measure("batched_b8", &b8, iters));

    for i in 1..rows.len() {
        rows[i].speedup_vs_per_request = Some(rows[i].req_per_s / base_rate.max(1e-12));
    }

    let b4_speedup = rows[2].speedup_vs_per_request.unwrap_or(0.0);
    println!(
        "\nbatched_b4 vs per_request: x{b4_speedup:.2}  (acceptance gate: >= 2.0)"
    );
    write_json(if quick { "quick" } else { "full" }, &rows);

    if strict && b4_speedup < 2.0 {
        println!(
            "SERVE GATE FAILED: batched_b4 is only x{b4_speedup:.2} over per_request \
             (need >= 2.0)"
        );
        std::process::exit(1);
    }
    println!("\nserve bench OK");
}
