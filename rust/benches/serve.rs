//! Bench: end-to-end denoise *serving* throughput (ISSUE 3 + ISSUE 4).
//!
//! Runs the full coordinator path — queue → fair batcher → worker lanes —
//! on the native (host-CPU surrogate) backend, so it executes offline
//! with no artifacts and no PJRT. Five execution modes are measured:
//!
//! * `per_request`         — step-at-a-time, one dispatch per request-step
//!                           (the pre-ISSUE-3 serving loop; the baseline).
//! * `per_request_fused`   — one fused scan dispatch per request (§Perf L2).
//! * `batched_b4_unpooled` — cross-request batching with the retain-nothing
//!                           pool: every lease allocates (the PR 2
//!                           per-batch-allocating path).
//! * `batched_b4`          — the ISSUE 4 pooled zero-allocation hot path,
//!                           max_batch = 4.
//! * `batched_b8`          — same, max_batch = 8.
//!
//! After the closed-loop modes, the bench runs the **open-loop**
//! streaming scenarios of ISSUE 5 on the session API: requests arrive on
//! a fixed synthetic schedule (`try_submit`, no blocking), calibrated
//! against the just-measured pooled `batched_b4` throughput:
//!
//! * `nominal`       — 0.4x the measured capacity, queue sized to the
//!                     workload: the bounded queue must admit everything
//!                     (zero `QueueFull` rejections below capacity).
//! * `overload_10x`  — 10x the nominal arrival rate against a small
//!                     bounded queue: overload must be shed at admission
//!                     (`QueueFull` rejections, not OOM or unbounded
//!                     latency), and `shutdown()` must resolve every
//!                     admitted ticket.
//!
//! Both checks are smoke gates that run in every mode (quick included);
//! the per-scenario e2e latency percentiles (p50/p95/p99, streaming
//! estimator) land in `BENCH_serve_openloop.json` for the CI artifact.
//!
//! Next, the **mixed multi-mode** scenario of ISSUE 7: one session
//! serving U-net denoise plus ResNet-18 / VGG-16 classification
//! (`model_mix = unet:2,resnet18:1,vgg16:1`) open-loop at nominal load
//! with co-simulation on, so shutdown prices each mode's share of the
//! accelerator separately. Always-on gates (quick included): batches
//! never mix models, all three modes are served cleanly, and each mode
//! prices to a positive GOPs/mm² FoM on the 40 nm calibration. Per-mode
//! req/s, p50/p99, cycles, and FoM land in `BENCH_serve_mixed.json`.
//!
//! Last come the **failover** scenarios of ISSUE 6 on the sharded
//! fleet front door: a two-shard `ShardFleet` driven open-loop at half
//! the measured single-session capacity, once with no faults and once
//! with a deterministic mid-flight shard kill (`kill:0:2` — shard 0
//! dies claiming its third request). The always-on gates assert the
//! delivered set is *complete* (every offered request id delivered
//! exactly once — failover loses nothing) and that the kill actually
//! fired (failovers == 1); `--strict` additionally bounds the p99
//! under failover at 10x the no-fault fleet p99. Percentiles land in
//! `BENCH_serve_failover.json`.
//!
//! Finally the **scale-sweep capacity map** of ISSUE 8: a grid of
//! (shard count × traffic profile) cells, each an open-loop session or
//! fleet driven by a seeded `TrafficProfile` arrival schedule (uniform /
//! Poisson / OU / burst / ramp / sine), writing per-cell p50/p95/p99,
//! shed rate and failover counts to `BENCH_scale.json`. The default run
//! covers a quick slice (shards {1,2} × {uniform, ou, burst} at nominal
//! load plus one shedding overload cell); the `workflow_dispatch` CI
//! matrix job passes `--scale-only --scale-profiles P --scale-shards
//! 1,2,4` for the full map. Always-on gates: nominal cells shed
//! nothing and deliver everything, the overload cell sheds, and a
//! recorded trace re-parses request-for-request and replays to
//! bit-identical results. Flags: `--scale-only` (skip everything else,
//! calibrate + sweep), `--scale-profiles LIST` (shorthand names or full
//! specs like `ou:80:2:20`), `--scale-shards LIST`, `--scale-requests N`.
//!
//! Run: `cargo bench --bench serve` (full) or `-- --quick` (CI profile).
//! Results go to `BENCH_serve.json`. Every run (quick included) asserts
//! the steady-state zero-allocation contract: the pooled `batched_b4`
//! session's `pool_misses` must stay inside the warmup working set (it
//! must not scale with the batch count) and the majority of leases must
//! hit the free list. With `--strict` the process additionally exits 1
//! unless pooled batched_b4 sustains >= 2x (ISSUE 3 gate) and >= 1.3x
//! (ISSUE 4 gate) the per-request-allocating requests/sec, and at least
//! 0.8x the unpooled batched path (the pooling-regression floor).
//! `--check-against <baseline.json>` compares against a committed
//! baseline via `util::bench::compare_baselines` (>15% drop fails; see
//! the hotpath bench for the same pattern).

use std::time::{Duration, Instant};

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{
    read_trace, recorded_workload, workload, write_trace, AdmissionError, DenoiseResult,
    DiffusionServer, ServeMetrics, ShardFleet, TrafficProfile,
};
use sf_mmcn::runtime::ArtifactStore;
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::bench::{check_against_baseline, BaselineRow, BenchBaseline};

/// Serving workers in every measured config (keep in sync with the
/// pool-warmup bound below).
const WORKERS: usize = 2;

/// Warmup allowance per worker lane: with the capacity-1 prep channel at
/// most three batches can hold prep slabs concurrently during cold start
/// (executing + buffered + being-prepared, 4 slabs each) plus the
/// rotating image slabs (one whole-request, two chunked) — at most 14;
/// 16 leaves slack. Misses beyond this mean the pool is not recycling
/// (the steady-state zero-allocation contract is broken).
const POOL_WARMUP_SLABS: u64 = 16;

struct Row {
    name: String,
    requests: usize,
    steps: usize,
    wall_s: f64,
    req_per_s: f64,
    occupancy: f64,
    dispatches: usize,
    stalls: usize,
    pool_hits: u64,
    pool_misses: u64,
    pool_mb_leased: f64,
    speedup_vs_per_request: Option<f64>,
    speedup_vs_unpooled: Option<f64>,
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn write_json(mode: &str, rows: &[Row]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", r.name));
        s.push_str(&format!("\"requests\": {}, ", r.requests));
        s.push_str(&format!("\"steps\": {}, ", r.steps));
        s.push_str(&format!("\"wall_s\": {}, ", json_f64(r.wall_s)));
        s.push_str(&format!("\"req_per_s\": {}, ", json_f64(r.req_per_s)));
        s.push_str(&format!(
            "\"batch_occupancy\": {}, ",
            json_f64(r.occupancy)
        ));
        s.push_str(&format!("\"dispatches\": {}, ", r.dispatches));
        s.push_str(&format!("\"pipeline_stalls\": {}, ", r.stalls));
        s.push_str(&format!("\"pool_hits\": {}, ", r.pool_hits));
        s.push_str(&format!("\"pool_misses\": {}, ", r.pool_misses));
        s.push_str(&format!(
            "\"pool_mb_leased\": {}",
            json_f64(r.pool_mb_leased)
        ));
        if let Some(sp) = r.speedup_vs_per_request {
            s.push_str(&format!(", \"speedup_vs_per_request\": {}", json_f64(sp)));
        }
        if let Some(sp) = r.speedup_vs_unpooled {
            s.push_str(&format!(", \"speedup_vs_unpooled\": {}", json_f64(sp)));
        }
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve.json", &s) {
        Ok(()) => println!("\nwrote BENCH_serve.json ({} results)", rows.len()),
        Err(e) => println!("\nWARNING: could not write BENCH_serve.json: {e}"),
    }
}

fn base_cfg(steps: usize, requests: usize) -> ServeConfig {
    ServeConfig {
        steps,
        requests,
        workers: WORKERS,
        max_batch: 1,
        seed: 7,
        artifact: "unet_denoise_16".into(),
        cosim: false,
        fused: false,
        backend: ServeBackend::Native,
        batched: false,
        pipeline: true,
        chunk: 0,
        pooled: true,
        ..ServeConfig::default()
    }
}

/// Serve the workload once; panics on any serving error (this bench IS
/// the offline health check of the serving stack).
fn serve_once(cfg: &ServeConfig) -> ServeMetrics {
    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store).expect("native server");
    let reqs = workload(cfg, cfg.seed, 0..cfg.requests);
    let (results, metrics) = server.serve(reqs).expect("serve");
    assert_eq!(
        results.len(),
        cfg.requests,
        "every request must be answered exactly once"
    );
    metrics
}

/// Run a mode `iters` times and keep its best (max-throughput) session —
/// same best-of policy as wall-clock benchmarks use against noise.
fn measure(name: &str, cfg: &ServeConfig, iters: usize) -> Row {
    let mut best: Option<ServeMetrics> = None;
    for _ in 0..iters {
        let m = serve_once(cfg);
        let better = match &best {
            Some(b) => m.requests_per_s() > b.requests_per_s(),
            None => true,
        };
        if better {
            best = Some(m);
        }
    }
    let m = best.expect("at least one iteration");
    println!(
        "bench serve::{name:<22} {:>8.1} req/s  ({} req x {} steps, wall {:.3}s, \
         occupancy {:.2}, {} dispatches, {} stalls, pool {}h/{}m)",
        m.requests_per_s(),
        cfg.requests,
        cfg.steps,
        m.wall.as_secs_f64(),
        m.batch_occupancy(),
        m.dispatches,
        m.pipeline_stalls,
        m.pool_hits,
        m.pool_misses,
    );
    Row {
        name: name.to_string(),
        requests: cfg.requests,
        steps: cfg.steps,
        wall_s: m.wall.as_secs_f64(),
        req_per_s: m.requests_per_s(),
        occupancy: m.batch_occupancy(),
        dispatches: m.dispatches,
        stalls: m.pipeline_stalls,
        pool_hits: m.pool_hits,
        pool_misses: m.pool_misses,
        pool_mb_leased: m.pool_bytes_leased as f64 / 1e6,
        speedup_vs_per_request: None,
        speedup_vs_unpooled: None,
    }
}

/// Steady-state zero-allocation smoke check (runs in every mode, quick
/// included): the pooled session's misses must stay inside the warmup
/// working set — a miss count that scales with the number of batches
/// means slabs are not recycling. `require_hit_majority` additionally
/// demands most leases hit the free list; that only holds when the
/// session runs several steady-state batches per worker (b4's 6/worker;
/// b8's 3/worker is mostly warmup, so it gets the miss bound only).
/// Returns false on violation (the caller exits once, after the JSON is
/// on disk).
fn check_pool_steady_state(row: &Row, require_hit_majority: bool) -> bool {
    let bound = POOL_WARMUP_SLABS * WORKERS as u64;
    if row.pool_misses > bound {
        println!(
            "POOL GATE FAILED: {} leased-allocated {} times (> warmup bound {bound}) — \
             pool_misses must stay flat after warmup",
            row.name, row.pool_misses
        );
        return false;
    }
    if require_hit_majority && row.pool_hits <= row.pool_misses {
        println!(
            "POOL GATE FAILED: {} served only {} leases from the free list vs {} \
             allocations — the steady state must be dominated by hits",
            row.name, row.pool_hits, row.pool_misses
        );
        return false;
    }
    println!(
        "pool steady-state OK: {} ({} hits / {} misses, bound {bound}), {:.2} MB leased",
        row.name, row.pool_hits, row.pool_misses, row.pool_mb_leased
    );
    true
}

// ------------------------------------------- open-loop scenarios (ISSUE 5)

struct OpenRow {
    name: String,
    target_rps: f64,
    offered: usize,
    admitted: u64,
    rejected_full: u64,
    expired: u64,
    completed: usize,
    failed: usize,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    wall_s: f64,
    queue_depth: usize,
}

/// One open-loop session: `n` requests arrive on a fixed schedule at
/// `rate` req/s via `try_submit` (overload is shed, never queued beyond
/// `queue_depth`), then the session drains gracefully. Panics if any
/// admitted ticket fails to resolve — `shutdown()` owing tickets is a
/// serving bug, not a perf regression.
fn run_open_loop(name: &str, steps: usize, n: usize, rate: f64, queue_depth: usize) -> OpenRow {
    let mut cfg = base_cfg(steps, n);
    cfg.batched = true;
    cfg.max_batch = 4;
    cfg.queue_depth = queue_depth;
    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store).expect("native server");
    let handle = server.start();
    let reqs = workload(&cfg, cfg.seed, 0..n);
    let interval = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n);
    let mut shed = 0usize;
    for (i, req) in reqs.into_iter().enumerate() {
        // fixed synthetic arrival schedule: request i is due at i/rate
        if let Some(sleep) = interval.mul_f64(i as f64).checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        match handle.try_submit(req) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => shed += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    let mut completed = 0usize;
    let mut failed = 0usize;
    for t in tickets {
        match t.wait() {
            Ok(_) => completed += 1,
            Err(_) => failed += 1,
        }
    }
    let m = handle.shutdown().expect("graceful drain");
    assert_eq!(
        completed + failed,
        m.admission.admitted as usize,
        "shutdown() must resolve every admitted ticket"
    );
    assert_eq!(
        shed as u64, m.admission.rejected_queue_full,
        "client-side and server-side QueueFull counts agree"
    );
    let row = OpenRow {
        name: name.to_string(),
        target_rps: rate,
        offered: n,
        admitted: m.admission.admitted,
        rejected_full: m.admission.rejected_queue_full,
        expired: m.admission.expired,
        completed,
        failed,
        p50_ms: m.e2e_latency.p50_us() / 1e3,
        p95_ms: m.e2e_latency.p95_us() / 1e3,
        p99_ms: m.e2e_latency.p99_us() / 1e3,
        wall_s: m.wall.as_secs_f64(),
        queue_depth,
    };
    println!(
        "bench serve::open_loop_{:<13} target {:>7.1} req/s  offered {:>3}  admitted {:>3}  \
         shed {:>3}  e2e p50 {:.2} ms  p95 {:.2}  p99 {:.2}  wall {:.3}s",
        row.name,
        row.target_rps,
        row.offered,
        row.admitted,
        row.rejected_full,
        row.p50_ms,
        row.p95_ms,
        row.p99_ms,
        row.wall_s,
    );
    row
}

/// `BENCH_serve_openloop.json`: the latency-percentile artifact CI
/// uploads (written before any gate can fire).
fn write_openloop_json(mode: &str, capacity_rps: f64, rows: &[OpenRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve_openloop\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str(&format!(
        "  \"calibrated_capacity_rps\": {},\n",
        json_f64(capacity_rps)
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", r.name));
        s.push_str(&format!("\"target_rps\": {}, ", json_f64(r.target_rps)));
        s.push_str(&format!("\"offered\": {}, ", r.offered));
        s.push_str(&format!("\"admitted\": {}, ", r.admitted));
        s.push_str(&format!("\"rejected_queue_full\": {}, ", r.rejected_full));
        s.push_str(&format!("\"expired\": {}, ", r.expired));
        s.push_str(&format!("\"completed\": {}, ", r.completed));
        s.push_str(&format!("\"failed\": {}, ", r.failed));
        s.push_str(&format!("\"queue_depth\": {}, ", r.queue_depth));
        s.push_str(&format!("\"p50_ms\": {}, ", json_f64(r.p50_ms)));
        s.push_str(&format!("\"p95_ms\": {}, ", json_f64(r.p95_ms)));
        s.push_str(&format!("\"p99_ms\": {}, ", json_f64(r.p99_ms)));
        s.push_str(&format!("\"wall_s\": {}", json_f64(r.wall_s)));
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve_openloop.json", &s) {
        Ok(()) => println!("wrote BENCH_serve_openloop.json ({} scenarios)", rows.len()),
        Err(e) => println!("WARNING: could not write BENCH_serve_openloop.json: {e}"),
    }
}

// --------------------------------------- mixed multi-mode traffic (ISSUE 7)

/// Per-mode slice of one mixed open-loop session: serving stats plus the
/// co-simulated accelerator figures for that model's share of the work.
struct MixedRow {
    model: &'static str,
    done: usize,
    failed: usize,
    steps: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    sim_cycles: Option<u64>,
    sim_gops: Option<f64>,
    sim_gops_per_mm2: Option<f64>,
    sim_u_pe: Option<f64>,
}

struct MixedRun {
    model_mix: String,
    target_rps: f64,
    offered: usize,
    admitted: u64,
    cross_model_batches: usize,
    wall_s: f64,
    rows: Vec<MixedRow>,
}

/// One mixed-traffic open-loop session (ISSUE 7): U-net denoise plus
/// ResNet-18 / VGG-16 classification interleaved 2:1:1 on the arrival
/// schedule, co-simulation on, so shutdown prices each mode's share of
/// the work separately on the 40 nm calibration — the per-mode GOPs/mm²
/// FoM the paper's multi-mode comparison tables report.
fn run_mixed(steps: usize, n: usize, rate: f64) -> MixedRun {
    let mut cfg = base_cfg(steps, n);
    cfg.batched = true;
    cfg.max_batch = 4;
    cfg.queue_depth = n; // sized to the workload: admission never sheds
    cfg.cosim = true;
    cfg.model_mix = "unet:2,resnet18:1,vgg16:1".into();
    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store).expect("native mixed server");
    let handle = server.start();
    let reqs = workload(&cfg, cfg.seed, 0..n);
    let interval = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for (i, req) in reqs.into_iter().enumerate() {
        // fixed synthetic arrival schedule: request i is due at i/rate
        if let Some(sleep) = interval.mul_f64(i as f64).checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        tickets.push(
            handle
                .try_submit(req)
                .expect("queue is sized to the workload"),
        );
    }
    let mut failed_waits = 0usize;
    for t in tickets {
        if t.wait().is_err() {
            failed_waits += 1;
        }
    }
    let m = handle.shutdown().expect("graceful drain");
    assert_eq!(failed_waits, 0, "mixed traffic must not fail any ticket");
    let wall = m.wall.as_secs_f64().max(1e-9);
    let rows: Vec<MixedRow> = m
        .per_model
        .iter()
        .filter(|r| r.requests_done + r.requests_failed > 0)
        .map(|r| {
            let rep = r.sim_report(&CAL_40NM, 8);
            MixedRow {
                model: r.model.name(),
                done: r.requests_done,
                failed: r.requests_failed,
                steps: r.steps_done,
                req_per_s: r.requests_done as f64 / wall,
                p50_ms: r.e2e_latency.p50_us() / 1e3,
                p99_ms: r.e2e_latency.p99_us() / 1e3,
                sim_cycles: rep.as_ref().map(|p| p.cycles),
                sim_gops: rep.as_ref().map(|p| p.gops),
                sim_gops_per_mm2: rep.as_ref().map(|p| p.gops_per_mm2),
                sim_u_pe: rep.as_ref().map(|p| p.u_pe),
            }
        })
        .collect();
    for r in &rows {
        println!(
            "bench serve::mixed_{:<9} {:>3} done  {:>4} steps  {:>7.1} req/s  \
             e2e p50 {:.2} ms  p99 {:.2} ms  sim {} cycles  {:.1} GOPs/mm2",
            r.model,
            r.done,
            r.steps,
            r.req_per_s,
            r.p50_ms,
            r.p99_ms,
            r.sim_cycles.unwrap_or(0),
            r.sim_gops_per_mm2.unwrap_or(0.0),
        );
    }
    MixedRun {
        model_mix: cfg.model_mix,
        target_rps: rate,
        offered: n,
        admitted: m.admission.admitted,
        cross_model_batches: m.cross_model_batches,
        wall_s: m.wall.as_secs_f64(),
        rows,
    }
}

fn opt_u64(v: Option<u64>) -> String {
    v.map_or("null".to_string(), |x| x.to_string())
}

/// `BENCH_serve_mixed.json`: the per-mode serving + co-sim artifact CI
/// uploads (written before any gate can fire).
fn write_mixed_json(mode: &str, run: &MixedRun) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve_mixed\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str(&format!("  \"model_mix\": \"{}\",\n", run.model_mix));
    s.push_str(&format!(
        "  \"target_rps\": {},\n",
        json_f64(run.target_rps)
    ));
    s.push_str(&format!("  \"offered\": {},\n", run.offered));
    s.push_str(&format!("  \"admitted\": {},\n", run.admitted));
    s.push_str(&format!(
        "  \"cross_model_batches\": {},\n",
        run.cross_model_batches
    ));
    s.push_str(&format!("  \"wall_s\": {},\n", json_f64(run.wall_s)));
    s.push_str("  \"results\": [\n");
    for (i, r) in run.rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"model\": \"{}\", ", r.model));
        s.push_str(&format!("\"requests_done\": {}, ", r.done));
        s.push_str(&format!("\"requests_failed\": {}, ", r.failed));
        s.push_str(&format!("\"steps_done\": {}, ", r.steps));
        s.push_str(&format!("\"req_per_s\": {}, ", json_f64(r.req_per_s)));
        s.push_str(&format!("\"p50_ms\": {}, ", json_f64(r.p50_ms)));
        s.push_str(&format!("\"p99_ms\": {}, ", json_f64(r.p99_ms)));
        s.push_str(&format!("\"sim_cycles\": {}, ", opt_u64(r.sim_cycles)));
        s.push_str(&format!(
            "\"sim_gops\": {}, ",
            r.sim_gops.map_or("null".into(), json_f64)
        ));
        s.push_str(&format!(
            "\"sim_gops_per_mm2\": {}, ",
            r.sim_gops_per_mm2.map_or("null".into(), json_f64)
        ));
        s.push_str(&format!(
            "\"sim_u_pe\": {}",
            r.sim_u_pe.map_or("null".into(), json_f64)
        ));
        s.push('}');
        if i + 1 < run.rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve_mixed.json", &s) {
        Ok(()) => println!("wrote BENCH_serve_mixed.json ({} modes)", run.rows.len()),
        Err(e) => println!("WARNING: could not write BENCH_serve_mixed.json: {e}"),
    }
}

// --------------------------------------- fleet failover scenarios (ISSUE 6)

struct FailoverRow {
    name: String,
    shards: usize,
    fault_spec: String,
    target_rps: f64,
    offered: usize,
    delivered: u64,
    failed: u64,
    failovers: u64,
    requeued: u64,
    dead: usize,
    live: usize,
    delivered_set_complete: bool,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    wall_s: f64,
}

/// One open-loop fleet session: `n` requests arrive on a fixed schedule
/// at `rate` req/s through the two-shard front door (`submit`, which
/// never sheds — the queue is sized to the workload), optionally with an
/// injected fault schedule. Per-step dispatches (`chunk = 1`) keep the
/// heartbeat gap to one native step, far inside the default tolerance.
/// Completeness of the delivered id set is recorded, not asserted — the
/// caller gates on it after the JSON is on disk.
fn run_failover(name: &str, steps: usize, n: usize, rate: f64, fault_spec: &str) -> FailoverRow {
    let mut cfg = base_cfg(steps, n);
    cfg.batched = true;
    cfg.max_batch = 4;
    cfg.pipeline = false;
    cfg.chunk = 1;
    cfg.queue_depth = n.max(8);
    cfg.shards = 2;
    cfg.fault_spec = fault_spec.to_string();
    let store = ArtifactStore::default_store();
    let fleet = ShardFleet::start(cfg.clone(), &store).expect("fleet start");
    let reqs = workload(&cfg, cfg.seed, 0..n);
    let interval = Duration::from_secs_f64(1.0 / rate.max(1e-9));
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for (i, req) in reqs.into_iter().enumerate() {
        // fixed synthetic arrival schedule: request i is due at i/rate
        if let Some(sleep) = interval.mul_f64(i as f64).checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        tickets.push(fleet.submit(req).expect("fleet front door admits the workload"));
    }
    let mut delivered_ids: Vec<u64> = Vec::with_capacity(n);
    let mut failed = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(r) => delivered_ids.push(r.id),
            Err(_) => failed += 1,
        }
    }
    let m = fleet.shutdown().expect("fleet shutdown");
    delivered_ids.sort_unstable();
    let complete = delivered_ids.len() == n
        && delivered_ids.iter().enumerate().all(|(i, &id)| id == i as u64);
    let row = FailoverRow {
        name: name.to_string(),
        shards: m.stats.shards,
        fault_spec: fault_spec.to_string(),
        target_rps: rate,
        offered: n,
        delivered: m.stats.delivered,
        failed,
        failovers: m.stats.failovers,
        requeued: m.stats.requeued,
        dead: m.stats.dead,
        live: m.stats.live,
        delivered_set_complete: complete,
        p50_ms: m.e2e_latency.p50_us() / 1e3,
        p95_ms: m.e2e_latency.p95_us() / 1e3,
        p99_ms: m.e2e_latency.p99_us() / 1e3,
        wall_s: m.wall.as_secs_f64(),
    };
    println!(
        "bench serve::fleet_{:<10} target {:>7.1} req/s  offered {:>3}  delivered {:>3}  \
         failovers {}  requeued {:>2}  e2e p50 {:.2} ms  p95 {:.2}  p99 {:.2}  wall {:.3}s",
        row.name,
        row.target_rps,
        row.offered,
        row.delivered,
        row.failovers,
        row.requeued,
        row.p50_ms,
        row.p95_ms,
        row.p99_ms,
        row.wall_s,
    );
    row
}

/// `BENCH_serve_failover.json`: the failover-latency artifact CI uploads
/// (written before any gate can fire).
fn write_failover_json(mode: &str, rows: &[FailoverRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve_failover\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", r.name));
        s.push_str(&format!("\"shards\": {}, ", r.shards));
        s.push_str(&format!("\"fault_spec\": \"{}\", ", r.fault_spec));
        s.push_str(&format!("\"target_rps\": {}, ", json_f64(r.target_rps)));
        s.push_str(&format!("\"offered\": {}, ", r.offered));
        s.push_str(&format!("\"delivered\": {}, ", r.delivered));
        s.push_str(&format!("\"failed\": {}, ", r.failed));
        s.push_str(&format!("\"failovers\": {}, ", r.failovers));
        s.push_str(&format!("\"requeued\": {}, ", r.requeued));
        s.push_str(&format!("\"dead\": {}, ", r.dead));
        s.push_str(&format!("\"live\": {}, ", r.live));
        s.push_str(&format!(
            "\"delivered_set_complete\": {}, ",
            r.delivered_set_complete
        ));
        s.push_str(&format!("\"p50_ms\": {}, ", json_f64(r.p50_ms)));
        s.push_str(&format!("\"p95_ms\": {}, ", json_f64(r.p95_ms)));
        s.push_str(&format!("\"p99_ms\": {}, ", json_f64(r.p99_ms)));
        s.push_str(&format!("\"wall_s\": {}", json_f64(r.wall_s)));
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve_failover.json", &s) {
        Ok(()) => println!("wrote BENCH_serve_failover.json ({} scenarios)", rows.len()),
        Err(e) => println!("WARNING: could not write BENCH_serve_failover.json: {e}"),
    }
}

// ------------------------------- scale-sweep capacity map (ISSUE 8)

/// One (shard count × traffic profile × queue depth) cell of the
/// capacity map: offered/admitted/shed accounting plus the client-side
/// e2e latency percentiles at that operating point.
struct ScaleCell {
    name: String,
    shards: usize,
    profile: String,
    queue_depth: usize,
    target_mean_rps: f64,
    offered: usize,
    admitted: u64,
    shed: u64,
    shed_rate: f64,
    delivered: usize,
    failed: usize,
    failovers: u64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    wall_s: f64,
    req_per_s: f64,
}

/// Map a `--scale-profiles` entry onto a concrete profile at `rate`
/// mean req/s. Shorthand names parameterize off the calibrated rate
/// (so one matrix job definition works at any measured capacity); an
/// entry containing `:` is parsed as a full spec verbatim.
fn profile_for(key: &str, rate: f64) -> TrafficProfile {
    match key {
        "uniform" => TrafficProfile::Uniform { rate },
        "poisson" => TrafficProfile::Poisson { rate },
        "ou" => TrafficProfile::Ou {
            mean: rate,
            theta: 2.0,
            sigma: rate * 0.25,
        },
        // duty-cycle-weighted mean = 0.75r + 2.25r * 0.1 = 0.975r ≈ r
        "burst" => TrafficProfile::Burst {
            base: rate * 0.75,
            peak: rate * 3.0,
            period_ms: 1000.0,
            burst_ms: 100.0,
        },
        "ramp" => TrafficProfile::Ramp {
            from: rate * 0.5,
            to: rate,
            ramp_ms: 2000.0,
        },
        "sine" => TrafficProfile::Sine {
            base: rate,
            amp: rate * 0.5,
            period_ms: 1000.0,
        },
        spec => TrafficProfile::parse(spec)
            .expect("--scale-profiles entries are shorthand names or full traffic specs"),
    }
}

/// One open-loop cell: `n` requests arrive on the profile's seeded
/// schedule via `try_submit` (overload shed, never parked), then the
/// session/fleet drains gracefully. Single-shard cells run the pooled
/// pipelined session; multi-shard cells run the fleet front door with
/// per-step dispatches (same settings as the failover scenarios).
fn run_scale_cell(
    name: &str,
    steps: usize,
    n: usize,
    shards: usize,
    profile: &TrafficProfile,
    queue_depth: usize,
) -> ScaleCell {
    let mut cfg = base_cfg(steps, n);
    cfg.batched = true;
    cfg.max_batch = 4;
    cfg.queue_depth = queue_depth;
    cfg.shards = shards;
    if shards > 1 {
        // per-step dispatches keep the heartbeat gap to one native step
        cfg.pipeline = false;
        cfg.chunk = 1;
    }
    let store = ArtifactStore::default_store();
    let reqs = workload(&cfg, cfg.seed, 0..n);
    let arrivals = profile.schedule(cfg.seed, n);
    let mut shed = 0u64;
    let (mut delivered, mut failed) = (0usize, 0usize);
    let (admitted, failovers, p50_ms, p95_ms, p99_ms, wall_s) = if shards > 1 {
        let fleet = ShardFleet::start(cfg.clone(), &store).expect("fleet start");
        let t0 = Instant::now();
        let mut tickets = Vec::with_capacity(n);
        for (req, &due_ns) in reqs.into_iter().zip(&arrivals) {
            if let Some(sleep) = Duration::from_nanos(due_ns).checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep);
            }
            match fleet.try_submit(req) {
                Ok(t) => tickets.push(t),
                Err(AdmissionError::QueueFull) => shed += 1,
                Err(e) => panic!("unexpected admission error: {e}"),
            }
        }
        for t in tickets {
            match t.wait() {
                Ok(_) => delivered += 1,
                Err(_) => failed += 1,
            }
        }
        let m = fleet.shutdown().expect("fleet shutdown");
        (
            m.stats.submitted,
            m.stats.failovers,
            m.e2e_latency.p50_us() / 1e3,
            m.e2e_latency.p95_us() / 1e3,
            m.e2e_latency.p99_us() / 1e3,
            m.wall.as_secs_f64(),
        )
    } else {
        let server = DiffusionServer::new(cfg.clone(), &store).expect("native server");
        let handle = server.start();
        let t0 = Instant::now();
        let mut tickets = Vec::with_capacity(n);
        for (req, &due_ns) in reqs.into_iter().zip(&arrivals) {
            if let Some(sleep) = Duration::from_nanos(due_ns).checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep);
            }
            match handle.try_submit(req) {
                Ok(t) => tickets.push(t),
                Err(AdmissionError::QueueFull) => shed += 1,
                Err(e) => panic!("unexpected admission error: {e}"),
            }
        }
        for t in tickets {
            match t.wait() {
                Ok(_) => delivered += 1,
                Err(_) => failed += 1,
            }
        }
        let m = handle.shutdown().expect("graceful drain");
        (
            m.admission.admitted,
            0,
            m.e2e_latency.p50_us() / 1e3,
            m.e2e_latency.p95_us() / 1e3,
            m.e2e_latency.p99_us() / 1e3,
            m.wall.as_secs_f64(),
        )
    };
    let cell = ScaleCell {
        name: name.to_string(),
        shards,
        profile: profile.render(),
        queue_depth,
        target_mean_rps: profile.mean_rate(),
        offered: n,
        admitted,
        shed,
        shed_rate: shed as f64 / n.max(1) as f64,
        delivered,
        failed,
        failovers,
        p50_ms,
        p95_ms,
        p99_ms,
        wall_s,
        req_per_s: delivered as f64 / wall_s.max(1e-9),
    };
    println!(
        "bench serve::scale_{:<22} `{}`  target {:>7.1} req/s  offered {:>3}  \
         delivered {:>3}  shed {:>3}  p50 {:.2} ms  p95 {:.2}  p99 {:.2}  wall {:.3}s",
        cell.name,
        cell.profile,
        cell.target_mean_rps,
        cell.offered,
        cell.delivered,
        cell.shed,
        cell.p50_ms,
        cell.p95_ms,
        cell.p99_ms,
        cell.wall_s,
    );
    cell
}

/// Run the (shards × profile) grid at nominal load (0.4× the calibrated
/// single-session capacity per shard, queue sized to the workload) plus
/// one shedding overload cell (4× capacity into a small bounded queue).
fn run_scale_sweep(
    quick: bool,
    steps: usize,
    capacity: f64,
    profiles: &[String],
    shards_list: &[usize],
    n: usize,
) -> Vec<ScaleCell> {
    println!("\n---- scale-sweep capacity map (shards x traffic profile) ----");
    let mut cells = Vec::new();
    for &shards in shards_list {
        let rate = 0.4 * capacity * shards as f64;
        for key in profiles {
            let profile = profile_for(key, rate);
            let name = format!("s{shards}_{key}_nominal");
            cells.push(run_scale_cell(&name, steps, n, shards, &profile, n));
        }
    }
    // overload: same operating point as open_loop_overload_10x — 4x the
    // calibrated capacity into a 2-batches-per-lane bounded queue
    let n_over = if quick { 80 } else { 120 };
    let overload = profile_for("uniform", 4.0 * capacity);
    cells.push(run_scale_cell(
        "s1_uniform_overload",
        steps,
        n_over,
        1,
        &overload,
        2 * WORKERS * 4,
    ));
    cells
}

/// `BENCH_scale.json`: the per-cell capacity map CI uploads (written
/// before any gate can fire).
fn write_scale_json(mode: &str, capacity_rps: f64, cells: &[ScaleCell]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"serve_scale\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str(&format!(
        "  \"calibrated_capacity_rps\": {},\n",
        json_f64(capacity_rps)
    ));
    s.push_str("  \"results\": [\n");
    for (i, c) in cells.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", c.name));
        s.push_str(&format!("\"shards\": {}, ", c.shards));
        s.push_str(&format!("\"profile\": \"{}\", ", c.profile));
        s.push_str(&format!("\"queue_depth\": {}, ", c.queue_depth));
        s.push_str(&format!(
            "\"target_mean_rps\": {}, ",
            json_f64(c.target_mean_rps)
        ));
        s.push_str(&format!("\"offered\": {}, ", c.offered));
        s.push_str(&format!("\"admitted\": {}, ", c.admitted));
        s.push_str(&format!("\"shed\": {}, ", c.shed));
        s.push_str(&format!("\"shed_rate\": {}, ", json_f64(c.shed_rate)));
        s.push_str(&format!("\"delivered\": {}, ", c.delivered));
        s.push_str(&format!("\"failed\": {}, ", c.failed));
        s.push_str(&format!("\"failovers\": {}, ", c.failovers));
        s.push_str(&format!("\"p50_ms\": {}, ", json_f64(c.p50_ms)));
        s.push_str(&format!("\"p95_ms\": {}, ", json_f64(c.p95_ms)));
        s.push_str(&format!("\"p99_ms\": {}, ", json_f64(c.p99_ms)));
        s.push_str(&format!("\"wall_s\": {}, ", json_f64(c.wall_s)));
        s.push_str(&format!("\"req_per_s\": {}", json_f64(c.req_per_s)));
        s.push('}');
        if i + 1 < cells.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_scale.json", &s) {
        Ok(()) => println!("wrote BENCH_scale.json ({} cells)", cells.len()),
        Err(e) => println!("WARNING: could not write BENCH_scale.json: {e}"),
    }
}

/// Always-on scale gates (quick included): nominal cells — queue sized
/// to the workload, load below capacity — must shed nothing and deliver
/// everything; the overload cell must actually shed (otherwise it
/// measured nothing). Returns true when all cells pass.
fn check_scale_gates(cells: &[ScaleCell]) -> bool {
    let mut ok = true;
    for c in cells {
        let clean = c.shed == 0 && c.failed == 0 && c.delivered == c.offered;
        if c.name.ends_with("_nominal") && !clean {
            println!(
                "SCALE GATE FAILED: {} delivered {}/{} with {} shed / {} failed — \
                 nominal cells must admit and deliver the whole workload",
                c.name, c.delivered, c.offered, c.shed, c.failed
            );
            ok = false;
        }
        if c.name.ends_with("_overload") && c.shed == 0 {
            println!(
                "SCALE GATE FAILED: {} shed nothing at {:.1} req/s against queue \
                 depth {} — overload must be shed at admission, not absorbed",
                c.name, c.target_mean_rps, c.queue_depth
            );
            ok = false;
        }
    }
    if ok {
        println!("scale gates OK: {} cells", cells.len());
    }
    ok
}

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a over `(id, image bits)` of every result, id-ordered — the
/// bit-identity fingerprint the trace gate compares.
fn results_digest(results: &[DenoiseResult]) -> u64 {
    let mut ordered: Vec<&DenoiseResult> = results.iter().collect();
    ordered.sort_by_key(|r| r.id);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for r in ordered {
        h = fnv1a(h, &r.id.to_le_bytes());
        for &v in &r.image.data {
            h = fnv1a(h, &v.to_bits().to_le_bytes());
        }
    }
    h
}

/// Always-on trace gate (ISSUE 8): record a mixed-model OU workload to
/// a JSON-lines trace, read it back, and serve both the recorded and
/// the reparsed request sequences — the trace must round-trip
/// request-for-request, and because request execution is a pure
/// function of `(model, seed, steps)` the replayed results must be
/// bit-identical. Returns true when both hold.
fn check_trace_roundtrip(steps: usize, quick: bool) -> bool {
    let n = if quick { 8 } else { 16 };
    let mut cfg = base_cfg(steps, n);
    cfg.batched = true;
    cfg.max_batch = 4;
    cfg.model_mix = "unet:2,resnet18:1,vgg16:1".into();
    let profile = TrafficProfile::Ou {
        mean: 200.0,
        theta: 2.0,
        sigma: 50.0,
    };
    let records = recorded_workload(&cfg, &profile, cfg.seed, n);
    let path = std::env::temp_dir().join("sf_mmcn_bench_scale_trace.jsonl");
    write_trace(&path, &records).expect("write trace");
    let back = read_trace(&path).expect("read trace");
    if back != records {
        println!(
            "TRACE GATE FAILED: reparsed trace differs from the recorded one \
             ({} vs {} records) — the JSON-lines format must round-trip exactly",
            back.len(),
            records.len()
        );
        return false;
    }
    let store = ArtifactStore::default_store();
    let recorded: Vec<_> = records.iter().map(|r| r.request.clone()).collect();
    let replayed: Vec<_> = back.into_iter().map(|r| r.request).collect();
    let (res_a, _) = DiffusionServer::new(cfg.clone(), &store)
        .expect("native server")
        .serve(recorded)
        .expect("serve recorded workload");
    let (res_b, _) = DiffusionServer::new(cfg.clone(), &store)
        .expect("native server")
        .serve(replayed)
        .expect("serve replayed workload");
    let (da, db) = (results_digest(&res_a), results_digest(&res_b));
    if da != db {
        println!(
            "TRACE GATE FAILED: replayed results digest {db:#018x} != recorded \
             {da:#018x} — replay must be bit-identical"
        );
        return false;
    }
    println!(
        "trace round-trip OK: {} records re-parse identically and replay to digest {da:#018x}",
        records.len()
    );
    true
}

/// CI regression gate: map this run's rows onto the shared comparator
/// (`util::bench::check_against_baseline`; >15% drop exits 1).
fn check_against(rows: &[Row], baseline_path: &str) {
    let current = BenchBaseline {
        provisional: false,
        rows: rows
            .iter()
            .map(|r| BaselineRow {
                name: r.name.clone(),
                mean_ns: None,
                mac_rate: Some(r.req_per_s),
                speedup_vs_ref: r.speedup_vs_per_request,
            })
            .collect(),
    };
    check_against_baseline(&current, baseline_path, "serve");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("SF_MMCN_BENCH_QUICK").is_ok();
    let strict = args.iter().any(|a| a == "--strict");
    let baseline_path = args
        .iter()
        .position(|a| a == "--check-against")
        .and_then(|i| args.get(i + 1).cloned());
    // Requests stay a multiple of max_batch x workers, and large enough
    // that the pooled lane runs several steady-state batches per worker
    // (the pool smoke check needs warmup to be a minority of the session).
    let (steps, requests, iters) = if quick { (4, 48, 2) } else { (16, 48, 3) };

    // Scale-sweep controls (ISSUE 8). The defaults are the quick slice
    // every run covers; the workflow_dispatch matrix job passes
    // explicit lists for the full capacity map.
    let scale_only = args.iter().any(|a| a == "--scale-only");
    let arg_after = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1).cloned())
    };
    let scale_profiles: Vec<String> = arg_after("--scale-profiles")
        .map(|s| {
            s.split(',')
                .map(|t| t.trim().to_string())
                .filter(|t| !t.is_empty())
                .collect()
        })
        .unwrap_or_else(|| vec!["uniform".into(), "ou".into(), "burst".into()]);
    let scale_shards: Vec<usize> = arg_after("--scale-shards")
        .map(|s| {
            s.split(',')
                .filter(|t| !t.trim().is_empty())
                .map(|t| {
                    t.trim()
                        .parse()
                        .expect("--scale-shards takes a comma list of shard counts")
                })
                .collect()
        })
        .unwrap_or_else(|| vec![1, 2]);
    let default_scale_requests = if quick { 24 } else { 40 };
    let scale_requests: usize = match arg_after("--scale-requests") {
        Some(s) => s.trim().parse().expect("--scale-requests takes an integer"),
        None => default_scale_requests,
    };

    if scale_only {
        println!(
            "==================== SERVE BENCH (scale-only{}) ====================\n\
             native surrogate backend, {WORKERS} workers, {scale_requests} requests x {steps} \
             steps per cell\n",
            if quick { ", quick" } else { "" }
        );
        // one calibration run of the pooled batched_b4 session fixes the
        // capacity every cell's target rate is expressed against
        let mut b4 = base_cfg(steps, requests);
        b4.batched = true;
        b4.max_batch = 4;
        let capacity = measure("batched_b4_calibration", &b4, 1).req_per_s.max(1e-6);
        let cells = run_scale_sweep(
            quick,
            steps,
            capacity,
            &scale_profiles,
            &scale_shards,
            scale_requests,
        );
        write_scale_json(if quick { "quick" } else { "full" }, capacity, &cells);
        let mut failed = !check_scale_gates(&cells);
        failed |= !check_trace_roundtrip(steps, quick);
        if failed {
            std::process::exit(1);
        }
        println!("\nserve bench OK (scale-only)");
        return;
    }

    println!(
        "==================== SERVE BENCH ({}) ====================\n\
         native surrogate backend, {WORKERS} workers, {requests} requests x {steps} steps\n",
        if quick { "quick" } else { "full" }
    );

    let mut rows = Vec::new();

    let per_request = measure("per_request", &base_cfg(steps, requests), iters);
    let base_rate = per_request.req_per_s;
    rows.push(per_request);

    let mut fused_cfg = base_cfg(steps, requests);
    fused_cfg.fused = true;
    rows.push(measure("per_request_fused", &fused_cfg, iters));

    let mut b4_unpooled = base_cfg(steps, requests);
    b4_unpooled.batched = true;
    b4_unpooled.max_batch = 4;
    b4_unpooled.pooled = false;
    rows.push(measure("batched_b4_unpooled", &b4_unpooled, iters));

    let mut b4 = base_cfg(steps, requests);
    b4.batched = true;
    b4.max_batch = 4;
    rows.push(measure("batched_b4", &b4, iters));

    let mut b8 = base_cfg(steps, requests);
    b8.batched = true;
    b8.max_batch = 8;
    rows.push(measure("batched_b8", &b8, iters));

    // ISSUE 9: the fused resident-x scan against the chunked dispatch
    // loop it replaces — same batching, same chunk setting, so the only
    // difference is per-chunk noise re-gather + slab ping-pong vs one
    // resident engine call per batch. (New rows ride along the JSON but
    // are deliberately absent from the committed baseline until a
    // re-baselining run records host-measured floors for them.)
    let mut b4_chunked = base_cfg(steps, requests);
    b4_chunked.batched = true;
    b4_chunked.max_batch = 4;
    b4_chunked.chunk = 4;
    rows.push(measure("batched_b4_chunk4", &b4_chunked, iters));

    let mut b4_resident = b4_chunked.clone();
    b4_resident.resident = true;
    rows.push(measure("batched_b4_resident", &b4_resident, iters));

    {
        let chunked = rows[rows.len() - 2].req_per_s;
        let resident = rows[rows.len() - 1].req_per_s;
        println!(
            "\nresident scan vs chunked dispatch loop: x{:.2} req/s \
             ({} -> {} dispatches)",
            resident / chunked.max(1e-12),
            rows[rows.len() - 2].dispatches,
            rows[rows.len() - 1].dispatches,
        );
    }

    for i in 1..rows.len() {
        rows[i].speedup_vs_per_request = Some(rows[i].req_per_s / base_rate.max(1e-12));
    }
    assert_eq!(rows[2].name, "batched_b4_unpooled");
    let unpooled_rate = rows[2].req_per_s;
    rows[3].speedup_vs_unpooled = Some(rows[3].req_per_s / unpooled_rate.max(1e-12));

    let b4_row = &rows[3];
    assert_eq!(b4_row.name, "batched_b4");
    let b4_speedup = b4_row.speedup_vs_per_request.unwrap_or(0.0);
    let b4_vs_unpooled = b4_row.speedup_vs_unpooled.unwrap_or(0.0);
    println!(
        "\npooled batched_b4 vs per_request: x{b4_speedup:.2}  \
         (ISSUE 3 gate >= 2.0, ISSUE 4 gate >= 1.3)\n\
         pooled batched_b4 vs unpooled:    x{b4_vs_unpooled:.2}  \
         (strict floor: >= 0.8)"
    );

    // JSON goes to disk before any gate can fire, so a failing run still
    // uploads its diagnostics from the CI artifact step.
    write_json(if quick { "quick" } else { "full" }, &rows);

    // Always-on pool contract checks (quick included): steady-state
    // zero-allocation for every pooled lane, pure allocation for the
    // unpooled baseline.
    assert_eq!(rows[4].name, "batched_b8");
    let mut failed = !check_pool_steady_state(b4_row, true);
    failed |= !check_pool_steady_state(&rows[4], false);
    if rows[2].pool_hits != 0 {
        println!(
            "POOL GATE FAILED: the unpooled baseline hit the free list {} times — \
             it must allocate every lease",
            rows[2].pool_hits
        );
        failed = true;
    }

    // ---- open-loop scenarios (ISSUE 5), calibrated to the measured
    // pooled batched_b4 capacity ----
    println!("\n---- open-loop streaming (session API) ----");
    let capacity = b4_row.req_per_s.max(1e-6);
    let nominal_rate = 0.4 * capacity;
    let overload_rate = 10.0 * nominal_rate;
    let (n_nominal, n_overload) = if quick { (32, 80) } else { (48, 120) };
    // Nominal: queue sized to the workload — below capacity the bounded
    // queue must never reject. Overload: a small bounded queue
    // (2 lanes x 2 batches of 4) — the 10x arrival surplus must be shed
    // at admission instead of ballooning memory or latency.
    let nominal = run_open_loop("nominal", steps, n_nominal, nominal_rate, n_nominal);
    let overload = run_open_loop(
        "overload_10x",
        steps,
        n_overload,
        overload_rate,
        2 * WORKERS * 4,
    );
    // JSON goes to disk before the gates so a failing run still uploads
    // its percentile diagnostics from the CI artifact step.
    let open_rows = [nominal, overload];
    write_openloop_json(if quick { "quick" } else { "full" }, capacity, &open_rows);
    let [nominal, overload] = &open_rows;

    // Smoke gates (always on, quick included): bounded-queue behaviour.
    if nominal.rejected_full != 0 {
        println!(
            "OPEN-LOOP GATE FAILED: {} QueueFull rejections at nominal load \
             ({:.1} req/s, 0.4x measured capacity) — below capacity the bounded \
             queue must admit everything",
            nominal.rejected_full, nominal.target_rps
        );
        failed = true;
    }
    if overload.rejected_full == 0 {
        println!(
            "OPEN-LOOP GATE FAILED: no QueueFull rejections under 10x overload \
             ({:.1} req/s against queue depth {}) — overload must be shed at \
             admission, not absorbed",
            overload.target_rps, overload.queue_depth
        );
        failed = true;
    }
    // ---- mixed multi-mode traffic (ISSUE 7): U-net + ResNet-18 + VGG-16
    // through one session, open-loop at nominal load, co-sim pricing each
    // mode's share of the accelerator separately ----
    println!("\n---- mixed multi-mode traffic (unet:2,resnet18:1,vgg16:1) ----");
    let n_mixed = if quick { 24 } else { 48 };
    let mixed = run_mixed(steps, n_mixed, nominal_rate);
    // JSON goes to disk before the gates so a failing run still uploads
    // its per-mode diagnostics from the CI artifact step.
    write_mixed_json(if quick { "quick" } else { "full" }, &mixed);

    // Always-on mixed-mode gates (quick included): the batcher must never
    // mix models in one dispatch, every mode must actually get served,
    // and each served mode must price to a positive area-efficiency FoM.
    if mixed.cross_model_batches != 0 {
        println!(
            "MIXED GATE FAILED: {} batch(es) mixed models in one dispatch — \
             batches must be model-pure",
            mixed.cross_model_batches
        );
        failed = true;
    }
    if mixed.rows.len() != 3 {
        println!(
            "MIXED GATE FAILED: only {} of 3 modes saw traffic under \
             model_mix {}",
            mixed.rows.len(),
            mixed.model_mix
        );
        failed = true;
    }
    for r in &mixed.rows {
        if r.failed != 0 || r.done == 0 {
            println!(
                "MIXED GATE FAILED: mode {} finished {} requests with {} \
                 failures — mixed traffic must serve every mode cleanly",
                r.model, r.done, r.failed
            );
            failed = true;
        }
        if r.sim_gops_per_mm2.unwrap_or(0.0) <= 0.0 {
            println!(
                "MIXED GATE FAILED: mode {} priced {:?} GOPs/mm2 — per-mode \
                 co-sim must report a positive area-efficiency FoM",
                r.model, r.sim_gops_per_mm2
            );
            failed = true;
        }
    }

    // ---- fleet failover scenarios (ISSUE 6): two shards, open-loop at
    // half the measured single-session capacity (the fleet doubles the
    // lane count, so post-kill the survivor still runs below capacity) ----
    println!("\n---- fleet failover (sharded front door) ----");
    let failover_rate = 0.5 * capacity;
    let n_fleet = if quick { 24 } else { 32 };
    let nofault = run_failover("nofault", steps, n_fleet, failover_rate, "");
    // kill:0:2 — shard 0 dies claiming its third request, mid-flight by
    // construction; deterministic and replayable from the spec string
    let kill = run_failover("kill_shard", steps, n_fleet, failover_rate, "kill:0:2");
    let failover_rows = [nofault, kill];
    write_failover_json(if quick { "quick" } else { "full" }, &failover_rows);
    let [nofault, kill] = &failover_rows;

    // Always-on failover gates: losing a shard mid-flight must lose no
    // work — the delivered id set stays complete — and the injected kill
    // must actually have fired (otherwise the scenario measured nothing).
    for r in &failover_rows {
        if !r.delivered_set_complete || r.failed != 0 {
            println!(
                "FAILOVER GATE FAILED: fleet_{} delivered {}/{} requests ({} failed) — \
                 the delivered set must be complete, faults or not",
                r.name, r.delivered, r.offered, r.failed
            );
            failed = true;
        }
    }
    if nofault.failovers != 0 {
        println!(
            "FAILOVER GATE FAILED: {} failovers in the no-fault fleet run — \
             healthy shards must never be retired",
            nofault.failovers
        );
        failed = true;
    }
    if kill.failovers != 1 || kill.dead != 1 {
        println!(
            "FAILOVER GATE FAILED: kill:0:2 produced {} failovers / {} dead shards \
             (expected exactly 1 of each) — the injected kill did not take effect",
            kill.failovers, kill.dead
        );
        failed = true;
    }
    if strict {
        // Failover must degrade latency, not wreck it: re-admitted work
        // restarts from scratch on the survivor, so the p99 roughly
        // doubles-to-triples; 10x the no-fault fleet p99 leaves room for
        // shared-runner noise while still catching a stuck monitor.
        if kill.p99_ms > 10.0 * nofault.p99_ms.max(1e-3) {
            println!(
                "FAILOVER GATE FAILED: p99 under failover is {:.2} ms vs {:.2} ms \
                 no-fault (strict bound: 10x) — recovery is stalling the fleet",
                kill.p99_ms, nofault.p99_ms
            );
            failed = true;
        }
    }

    // ---- scale-sweep capacity map + trace gates (ISSUE 8): the quick
    // slice runs in every mode; the workflow_dispatch matrix job runs
    // the full map via --scale-only ----
    let cells = run_scale_sweep(
        quick,
        steps,
        capacity,
        &scale_profiles,
        &scale_shards,
        scale_requests,
    );
    write_scale_json(if quick { "quick" } else { "full" }, capacity, &cells);
    failed |= !check_scale_gates(&cells);
    failed |= !check_trace_roundtrip(steps, quick);

    if strict {
        // Both named acceptance gates measure pooled batched_b4 against
        // the per-request-allocating path and are evaluated (and
        // reported) independently, so ISSUE 4's survives if ISSUE 3's
        // is ever retuned. The pooled-vs-unpooled ratio is deliberately
        // NOT gated at 1.3x: on the surrogate backend the per-dispatch
        // weight digest dominates a batch (~85% of its wall), so
        // removing the allocator from the loop moves that ratio only a
        // few percent — a >= 1.3x floor there would be structurally
        // red. It gets the regression floor below instead; the
        // zero-allocation contract itself is enforced exactly by the
        // pool_misses warmup bound above.
        if b4_speedup < 1.3 {
            println!(
                "SERVE GATE FAILED: pooled batched_b4 is only x{b4_speedup:.2} over \
                 the per-request-allocating path (ISSUE 4 gate: >= 1.3)"
            );
            failed = true;
        }
        if b4_speedup < 2.0 {
            println!(
                "SERVE GATE FAILED: pooled batched_b4 is only x{b4_speedup:.2} over \
                 per_request (ISSUE 3 gate: >= 2.0)"
            );
            failed = true;
        }
        // pooling must never fall materially behind the allocating path
        // it replaces (lock contention or zero-fill regressions trip
        // this floor first; 0.8 leaves room for shared-runner noise)
        if b4_vs_unpooled < 0.8 {
            println!(
                "SERVE GATE FAILED: pooled batched_b4 runs at x{b4_vs_unpooled:.2} \
                 of the unpooled allocating path (floor: >= 0.8)"
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
    if let Some(path) = baseline_path {
        check_against(&rows, &path);
    }
    println!("\nserve bench OK");
}
