//! Bench: design-choice ablations (zero gating, reuse registers, server
//! flow, buffer sizing) — the knobs DESIGN.md calls out.
//!
//! Run: `cargo bench --bench ablations`.

use sf_mmcn::report::{ablation_suite, fig19};
use sf_mmcn::util::bench::Bencher;

fn main() {
    println!("==================== ABLATIONS ====================\n");
    let (text, rows) = ablation_suite();
    println!("{text}");
    assert!(rows.len() >= 9);

    // Fig 19 rides along here (it is a dataflow illustration, not a sweep)
    let (text, (trad, sf)) = fig19();
    println!("{text}");
    assert!(sf < trad, "SF schedule must be shorter");

    println!("--- harness timings ---");
    let b = Bencher::quick();
    b.report("ablation_suite()", ablation_suite);
    println!("\nablations bench OK");
}
