//! Bench: hot paths of the three layers, for the §Perf optimization pass.
//!
//! * L3 simulator: micro-sim conv groups / full small nets (events/sec).
//! * L3 analytic: full-model analysis throughput (the bench workhorse).
//! * L3 runtime: PJRT execute latency for the SF block and the full U-net
//!   denoise step (the serving hot path), when artifacts are present.
//!
//! Run: `cargo bench --bench hotpath`. Before/after numbers are recorded
//! in EXPERIMENTS.md §Perf.

use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::coordinator::ddpm::time_embedding;
use sf_mmcn::coordinator::UnetParams;
use sf_mmcn::models::graph::{Act, GraphBuilder, Layer, Residual, TensorShape};
use sf_mmcn::models::{resnet18, unet, vgg16, UnetConfig};
use sf_mmcn::runtime::{ArtifactStore, Executor, TensorBuf};
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::sim::unit::{ConvGroup, ServerTask, SfMmcnUnit};
use sf_mmcn::quant::Fixed;
use sf_mmcn::util::bench::{fmt_rate, Bencher};
use sf_mmcn::util::{Rng, Tensor};

fn bench_unit_group(b: &Bencher) {
    let w: Vec<Fixed> = (0..9).map(|i| Fixed::from_f32(0.1 * i as f32)).collect();
    let wins: Vec<Vec<Fixed>> = (0..8)
        .map(|i| (0..9).map(|j| Fixed::from_f32((i + j) as f32 * 0.05)).collect())
        .collect();
    let mut unit = SfMmcnUnit::new();
    let r = b.report("unit::run_group 3x3 series (72 MACs)", || {
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 42,
        };
        unit.run_group(&g)
    });
    println!(
        "  -> simulated MAC rate: {}",
        fmt_rate(72.0 / (r.mean_ns / 1e9))
    );
}

fn bench_micro_sim(b: &Bencher) {
    let mut bld = GraphBuilder::new("bench", TensorShape::new(16, 32, 32));
    bld.add(Layer::Conv {
        c_in: 16,
        c_out: 16,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Relu,
        residual: Residual::None,
        time_dense: None,
    })
    .unwrap();
    bld.add(Layer::Conv {
        c_in: 16,
        c_out: 16,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::Identity { from: 0 },
        time_dense: None,
    })
    .unwrap();
    let g = bld.build();
    let ws = WeightStore::random(&g, 1);
    let mut rng = Rng::new(2);
    let x = Tensor::from_fn(&[16, 32, 32], |_| rng.normal() * 0.4);
    let macs = g.total_macs();
    let r = b.report("micro-sim residual pair 16ch@32 (9.4 M MACs)", || {
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        acc.run_graph(&g, &x, &ws, None).unwrap()
    });
    println!(
        "  -> simulated MAC rate: {}",
        fmt_rate(macs as f64 / (r.mean_ns / 1e9))
    );
}

fn bench_analytic(b: &Bencher) {
    let vgg = vgg16(224, 1000);
    let rn = resnet18(224, 1000);
    let un = unet(UnetConfig::default());
    let cfg = AcceleratorConfig::default();
    b.report("analyze_graph vgg16@224", || analyze_graph(&cfg, &vgg, 0.45));
    b.report("analyze_graph resnet18@224", || analyze_graph(&cfg, &rn, 0.45));
    b.report("analyze_graph unet16", || analyze_graph(&cfg, &un, 0.45));
}

fn bench_runtime(b: &Bencher) {
    let store = ArtifactStore::default_store();
    let Ok(spec) = store.resolve("sf_block_16") else {
        println!("(artifacts missing — skipping PJRT hot-path benches; run `make artifacts`)");
        return;
    };
    let mut exe = Executor::new().expect("pjrt client");
    exe.load_hlo_text("sf_block", &spec.path).expect("compile");
    let x = TensorBuf::new(vec![8, 16, 16], vec![0.3; 2048]).unwrap();
    let w = TensorBuf::new(vec![8, 8, 3, 3], vec![0.1; 576]).unwrap();
    let bias = TensorBuf::new(vec![8], vec![0.0; 8]).unwrap();
    let skip = TensorBuf::new(vec![8, 16, 16], vec![0.5; 2048]).unwrap();
    b.report("pjrt execute sf_block_16", || {
        exe.run("sf_block", &[x.clone(), w.clone(), bias.clone(), skip.clone()])
            .unwrap()
    });

    if let Ok(spec) = store.resolve("unet_denoise_16") {
        let params = UnetParams::load(store.root(), "unet_params").expect("params");
        exe.load_hlo_text("denoise", &spec.path).expect("compile denoise");
        let img = TensorBuf::new(vec![1, 16, 16], vec![0.1; 256]).unwrap();
        let emb = TensorBuf::new(vec![32], time_embedding(5.0, 32)).unwrap();
        let noise = TensorBuf::zeros(&[1, 16, 16]);
        let mut inputs = vec![
            img,
            emb,
            TensorBuf::scalar(1.01),
            TensorBuf::scalar(0.05),
            TensorBuf::scalar(0.1),
            noise,
        ];
        inputs.extend(params.tensors.iter().cloned());
        let r = b.report("pjrt execute unet_denoise_16 (naive: convert all 39)", || {
            exe.run("denoise", &inputs).unwrap()
        });
        // §Perf variant: params pre-converted once, 6 dynamic tensors/step
        let prepared = exe.prepare(&params.tensors).unwrap();
        let dynamic = inputs[..6].to_vec();
        let r2 = b.report("pjrt execute unet_denoise_16 (prepared params)", || {
            exe.run_prepared("denoise", &dynamic, &prepared).unwrap()
        });
        println!(
            "  -> serving ceiling: naive {:.1} -> prepared {:.1} steps/s/worker ({:+.1}%)",
            1e9 / r.mean_ns,
            1e9 / r2.mean_ns,
            100.0 * (r.mean_ns / r2.mean_ns - 1.0)
        );

        // §Perf L2: the fused 50-step scan artifact — one dispatch for the
        // whole reverse process.
        if let Ok(spec) = store.resolve("unet_denoise_scan50_16") {
            exe.load_hlo_text("scan", &spec.path).expect("compile scan");
            let t = 50usize;
            let mut t_embs = Vec::new();
            let mut coeffs = Vec::new();
            for s in (0..t).rev() {
                t_embs.extend(time_embedding(s as f32, 32));
                coeffs.extend([1.01f32, 0.05, if s > 0 { 0.1 } else { 0.0 }]);
            }
            let dynamic = vec![
                TensorBuf::new(vec![1, 16, 16], vec![0.1; 256]).unwrap(),
                TensorBuf::new(vec![t, 32], t_embs).unwrap(),
                TensorBuf::new(vec![t, 3], coeffs).unwrap(),
                TensorBuf::new(vec![t, 1, 16, 16], vec![0.0; t * 256]).unwrap(),
            ];
            let r3 = b.report("pjrt execute unet_denoise_scan50 (50 steps fused)", || {
                exe.run_prepared("scan", &dynamic, &prepared).unwrap()
            });
            println!(
                "  -> fused per-step: {:.3} ms vs step-at-a-time {:.3} ms (x{:.2})",
                r3.mean_ns / 50.0 / 1e6,
                r2.mean_ns / 1e6,
                r2.mean_ns / (r3.mean_ns / 50.0)
            );
        }
    }
}

fn main() {
    println!("==================== HOT-PATH BENCH ====================\n");
    let b = Bencher::default();
    bench_unit_group(&b);
    bench_micro_sim(&Bencher::quick());
    bench_analytic(&Bencher::quick());
    bench_runtime(&Bencher::quick());
    println!("\nhotpath bench OK");
}
