//! Bench: hot paths of the three layers, for the §Perf optimization pass.
//!
//! * L3 simulator: micro-sim conv groups / residual pairs / full VGG-16
//!   and ResNet-18 graphs — fast path vs the preserved reference path
//!   (`run_graph_ref`), so every run records the speedup against the
//!   pre-optimization baseline *measured on the same machine*.
//! * L3 analytic: full-model analysis throughput (the bench workhorse).
//! * L3 runtime: PJRT execute latency for the SF block and the full U-net
//!   denoise step (the serving hot path), when artifacts are present.
//!
//! Run: `cargo bench --bench hotpath` (full) or
//! `cargo bench --bench hotpath -- --quick` (CI profile: skips the
//! full-model simulations). Either mode writes machine-readable results
//! to `BENCH_hotpath.json` so the perf trajectory is tracked across PRs;
//! human-readable before/after tables live in EXPERIMENTS.md §Perf.
//! `-- --check-against benches/baseline/BENCH_hotpath.json` turns the run
//! into the CI regression gate: exit 1 on a >15% drop vs the baseline
//! (tolerance via `SF_MMCN_BENCH_TOLERANCE`, in percent).

use std::time::Duration;

use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::coordinator::ddpm::time_embedding;
use sf_mmcn::coordinator::UnetParams;
use sf_mmcn::models::graph::{Act, GraphBuilder, Layer, ModelGraph, Residual, TensorShape};
use sf_mmcn::models::{resnet18, unet, vgg16, UnetConfig};
use sf_mmcn::quant::Fixed;
use sf_mmcn::runtime::{
    step_kernel_scalar, ArtifactStore, BatchDispatch, Executor, NativeDenoise, TensorBuf,
};
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::sim::unit::{ConvGroup, FlatServer, ServerTask, SfMmcnUnit};
use sf_mmcn::util::bench::{
    check_against_baseline, BaselineRow, BenchBaseline, Bencher, fmt_rate,
};
use sf_mmcn::util::{Rng, Tensor};

/// One machine-readable result row for `BENCH_hotpath.json`.
struct JsonRow {
    name: String,
    mean_ns: f64,
    /// Model MACs simulated per iteration (sim benches only).
    macs: Option<u64>,
    /// Simulated MAC throughput, MAC/s (sim benches only).
    mac_rate: Option<f64>,
    /// Speedup vs the reference (pre-optimization) path, if measured.
    speedup_vs_ref: Option<f64>,
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn write_json(mode: &str, rows: &[JsonRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"hotpath\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!("\"name\": \"{}\", ", r.name));
        s.push_str(&format!("\"mean_ns\": {}", json_f64(r.mean_ns)));
        if let Some(m) = r.macs {
            s.push_str(&format!(", \"macs\": {m}"));
        }
        if let Some(rate) = r.mac_rate {
            s.push_str(&format!(", \"mac_rate_per_s\": {}", json_f64(rate)));
        }
        if let Some(sp) = r.speedup_vs_ref {
            s.push_str(&format!(", \"speedup_vs_ref\": {}", json_f64(sp)));
        }
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_hotpath.json", &s) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json ({} results)", rows.len()),
        Err(e) => println!("\nWARNING: could not write BENCH_hotpath.json: {e}"),
    }
}

fn bench_unit_group(b: &Bencher, rows: &mut Vec<JsonRow>) {
    let w: Vec<Fixed> = (0..9).map(|i| Fixed::from_f32(0.1 * i as f32)).collect();
    let wins: Vec<Vec<Fixed>> = (0..8)
        .map(|i| (0..9).map(|j| Fixed::from_f32((i + j) as f32 * 0.05)).collect())
        .collect();
    let mut unit = SfMmcnUnit::new();
    let r = b.report("unit::run_group 3x3 series (72 MACs)", || {
        let g = ConvGroup {
            windows: &wins,
            weights: &w,
            server: ServerTask::Idle,
            reused_inputs: 42,
        };
        unit.run_group(&g)
    });
    println!(
        "  -> simulated MAC rate: {}",
        fmt_rate(72.0 / (r.mean_ns / 1e9))
    );
    rows.push(JsonRow {
        name: "unit_run_group_3x3".into(),
        mean_ns: r.mean_ns,
        macs: Some(72),
        mac_rate: Some(72.0 / (r.mean_ns / 1e9)),
        speedup_vs_ref: None,
    });

    // §Perf flat path on the identical group.
    let flat: Vec<Fixed> = wins.iter().flatten().copied().collect();
    let zeros: Vec<u64> = wins
        .iter()
        .map(|win| win.iter().filter(|v| v.is_zero()).count() as u64)
        .collect();
    let mut unit2 = SfMmcnUnit::new();
    let mut outs: Vec<Fixed> = Vec::with_capacity(8);
    let rf = b.report("unit::run_group_flat 3x3 series (72 MACs)", || {
        unit2.run_group_flat(&flat, 8, 9, &zeros, &w, FlatServer::Idle, 42, &mut outs)
    });
    println!(
        "  -> simulated MAC rate: {}  (x{:.2} vs run_group)",
        fmt_rate(72.0 / (rf.mean_ns / 1e9)),
        r.mean_ns / rf.mean_ns
    );
    rows.push(JsonRow {
        name: "unit_run_group_flat_3x3".into(),
        mean_ns: rf.mean_ns,
        macs: Some(72),
        mac_rate: Some(72.0 / (rf.mean_ns / 1e9)),
        speedup_vs_ref: Some(r.mean_ns / rf.mean_ns),
    });
}

fn residual_pair_graph() -> ModelGraph {
    let mut bld = GraphBuilder::new("bench", TensorShape::new(16, 32, 32));
    bld.add(Layer::Conv {
        c_in: 16,
        c_out: 16,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::Relu,
        residual: Residual::None,
        time_dense: None,
    })
    .unwrap();
    bld.add(Layer::Conv {
        c_in: 16,
        c_out: 16,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::Identity { from: 0 },
        time_dense: None,
    })
    .unwrap();
    bld.build()
}

/// Bench a graph through the fast path and (optionally) the reference
/// path, pushing JSON rows with the measured speedup.
fn bench_sim_graph(
    b_fast: &Bencher,
    b_ref: Option<&Bencher>,
    name: &str,
    g: &ModelGraph,
    seed: u64,
    time_dim: Option<usize>,
    rows: &mut Vec<JsonRow>,
) {
    let ws = WeightStore::random(g, seed);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let x = Tensor::from_fn(&[g.input.c, g.input.h, g.input.w], |_| rng.normal() * 0.4);
    let emb: Option<Vec<f32>> =
        time_dim.map(|td| (0..td).map(|_| rng.normal() * 0.5).collect());
    let macs = g.total_macs();

    let r_fast = b_fast.report(&format!("micro-sim {name} [fast]"), || {
        let mut acc = Accelerator::new(AcceleratorConfig::default());
        acc.run_graph(g, &x, &ws, emb.as_deref()).unwrap()
    });
    println!(
        "  -> simulated MAC rate: {}",
        fmt_rate(macs as f64 / (r_fast.mean_ns / 1e9))
    );

    let speedup = b_ref.map(|br| {
        let r_ref = br.report(&format!("micro-sim {name} [reference]"), || {
            let mut acc = Accelerator::new(AcceleratorConfig::default());
            acc.run_graph_ref(g, &x, &ws, emb.as_deref()).unwrap()
        });
        println!(
            "  -> simulated MAC rate: {}  |  fast path speedup: x{:.2}",
            fmt_rate(macs as f64 / (r_ref.mean_ns / 1e9)),
            r_ref.mean_ns / r_fast.mean_ns
        );
        rows.push(JsonRow {
            name: format!("{name}_reference"),
            mean_ns: r_ref.mean_ns,
            macs: Some(macs),
            mac_rate: Some(macs as f64 / (r_ref.mean_ns / 1e9)),
            speedup_vs_ref: None,
        });
        r_ref.mean_ns / r_fast.mean_ns
    });

    rows.push(JsonRow {
        name: name.to_string(),
        mean_ns: r_fast.mean_ns,
        macs: Some(macs),
        mac_rate: Some(macs as f64 / (r_fast.mean_ns / 1e9)),
        speedup_vs_ref: speedup,
    });
}

/// ISSUE 9: the f32 step kernel in isolation — the scalar (default
/// build) path always, plus the `--features simd` path and the widening
/// Q8.8 dot when compiled in. The SIMD rows carry `speedup_vs_ref`
/// against the scalar rows measured in the same process, so the ratio
/// gates CI machine-independently.
fn bench_step_kernel(b: &Bencher, rows: &mut Vec<JsonRow>) {
    let n = 1usize << 16;
    let x0: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.0137).sin() * 1.5).collect();
    let noise: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.0071).cos() * 0.4).collect();
    let emb: Vec<f32> = (0..32).map(|i| (i as f32) * 0.03 - 0.4).collect();
    let mut x = x0.clone();
    let r_scalar = b.report("step_kernel scalar (64Ki f32)", || {
        x.copy_from_slice(&x0);
        step_kernel_scalar(&mut x, &emb, (1.01, 0.05, 0.1), &noise, (0.9, 0.3));
    });
    rows.push(JsonRow {
        name: "step_kernel_scalar_64k".into(),
        mean_ns: r_scalar.mean_ns,
        macs: None,
        mac_rate: None,
        speedup_vs_ref: None,
    });
    #[cfg(feature = "simd")]
    {
        use sf_mmcn::runtime::step_kernel_simd;
        use sf_mmcn::util::simd;
        let r_simd = b.report("step_kernel simd (64Ki f32)", || {
            x.copy_from_slice(&x0);
            step_kernel_simd(&mut x, &emb, (1.01, 0.05, 0.1), &noise, (0.9, 0.3));
        });
        println!(
            "  -> simd step kernel: x{:.2} vs scalar",
            r_scalar.mean_ns / r_simd.mean_ns
        );
        rows.push(JsonRow {
            name: "step_kernel_simd_64k".into(),
            mean_ns: r_simd.mean_ns,
            macs: None,
            mac_rate: None,
            speedup_vs_ref: Some(r_scalar.mean_ns / r_simd.mean_ns),
        });

        let m = 1usize << 14;
        let a: Vec<i16> = (0..m).map(|i| ((i * 37) % 30000) as i16 - 15000).collect();
        let bb: Vec<i16> = (0..m).map(|i| ((i * 101) % 30000) as i16 - 15000).collect();
        let r_dscalar = b.report("dot_wide scalar reference (16Ki i16)", || {
            a.iter()
                .zip(&bb)
                .map(|(&p, &q)| (p as i32 * q as i32) as i64)
                .sum::<i64>()
        });
        let r_dsimd = b.report("dot_wide simd (16Ki i16)", || simd::dot_wide_i16(&a, &bb));
        println!(
            "  -> simd widening dot: x{:.2} vs scalar",
            r_dscalar.mean_ns / r_dsimd.mean_ns
        );
        rows.push(JsonRow {
            name: "dot_wide_simd_16k".into(),
            mean_ns: r_dsimd.mean_ns,
            macs: Some(m as u64),
            mac_rate: Some(m as f64 / (r_dsimd.mean_ns / 1e9)),
            speedup_vs_ref: Some(r_dscalar.mean_ns / r_dsimd.mean_ns),
        });
    }
}

/// ISSUE 9: fused resident-x scan vs the chunked dispatch loop, at the
/// engine layer (no serving overhead in the way). The chunked reference
/// reproduces exactly what the serving lane does per chunk — slice the
/// step rows, re-gather each request's noise, ping-pong two image slabs
/// — and the resident row replaces all of it with one engine call over
/// a single hot slab.
fn bench_native_scan(b: &Bencher, rows: &mut Vec<JsonRow>) {
    let (bsz, steps, n, chunk) = (8usize, 50usize, 256usize, 10usize);
    let e = NativeDenoise::new(vec![1, 16, 16], 32);
    let params = vec![
        TensorBuf::new(vec![3], vec![0.1, -0.2, 0.3]).unwrap(),
        TensorBuf::new(vec![2, 2], vec![0.05, 0.0, -0.1, 0.2]).unwrap(),
    ];
    let x = TensorBuf::new(
        vec![bsz, 1, 16, 16],
        (0..bsz * n).map(|i| (i as f32) * 0.0021 - 0.3).collect(),
    )
    .unwrap();
    let t_embs = TensorBuf::new(
        vec![steps, 32],
        (0..steps * 32).map(|i| (i as f32) * 0.001 - 0.02).collect(),
    )
    .unwrap();
    let coeffs = {
        let mut c = Vec::new();
        for r in 0..steps {
            c.extend([1.002f32, 0.04, if r + 1 < steps { 0.06 } else { 0.0 }]);
        }
        TensorBuf::new(vec![steps, 3], c).unwrap()
    };
    let noises = TensorBuf::new(
        vec![bsz, steps, 1, 16, 16],
        (0..bsz * steps * n)
            .map(|i| ((i % 127) as f32) * 0.0007 - 0.04)
            .collect(),
    )
    .unwrap();

    let r_chunked = b.report("native scan chunked b8 x 50 steps (chunk 10)", || {
        let mut cur = x.data.clone();
        let mut dst = vec![0.0f32; bsz * n];
        let mut done = 0usize;
        while done < steps {
            let c = chunk.min(steps - done);
            let te =
                TensorBuf::new(vec![c, 32], t_embs.data[done * 32..(done + c) * 32].to_vec())
                    .unwrap();
            let co = TensorBuf::new(vec![c, 3], coeffs.data[done * 3..(done + c) * 3].to_vec())
                .unwrap();
            let mut nz = Vec::with_capacity(bsz * c * n);
            for i in 0..bsz {
                nz.extend_from_slice(
                    &noises.data[(i * steps + done) * n..(i * steps + done + c) * n],
                );
            }
            let no = TensorBuf::new(vec![bsz, c, 1, 16, 16], nz).unwrap();
            let cur_t = TensorBuf::new(x.shape.clone(), std::mem::take(&mut cur)).unwrap();
            let d = BatchDispatch {
                batch: bsz,
                steps: c,
                x: &cur_t,
                t_embs: &te,
                coeffs: &co,
                noises: &no,
            };
            e.run_batched_into(&d, &params, &mut dst).unwrap();
            cur = cur_t.data;
            std::mem::swap(&mut cur, &mut dst);
            done += c;
        }
        cur
    });
    rows.push(JsonRow {
        name: "native_scan_chunked_b8x50".into(),
        mean_ns: r_chunked.mean_ns,
        macs: None,
        mac_rate: None,
        speedup_vs_ref: None,
    });

    let d = BatchDispatch {
        batch: bsz,
        steps,
        x: &x,
        t_embs: &t_embs,
        coeffs: &coeffs,
        noises: &noises,
    };
    let mut out = vec![0.0f32; bsz * n];
    let r_resident = b.report("native scan resident b8 x 50 steps (fused)", || {
        e.run_scan_resident(&d, &params, &mut out, &|| {}).unwrap();
    });
    println!(
        "  -> resident scan: x{:.2} vs chunked dispatch loop",
        r_chunked.mean_ns / r_resident.mean_ns
    );
    rows.push(JsonRow {
        name: "native_scan_resident_b8x50".into(),
        mean_ns: r_resident.mean_ns,
        macs: None,
        mac_rate: None,
        speedup_vs_ref: Some(r_chunked.mean_ns / r_resident.mean_ns),
    });
}

fn bench_analytic(b: &Bencher, rows: &mut Vec<JsonRow>) {
    let vgg = vgg16(224, 1000);
    let rn = resnet18(224, 1000);
    let un = unet(UnetConfig::default());
    let cfg = AcceleratorConfig::default();
    for (name, g) in [
        ("analyze_vgg16_224", &vgg),
        ("analyze_resnet18_224", &rn),
        ("analyze_unet16", &un),
    ] {
        let r = b.report(&format!("analyze_graph {name}"), || {
            analyze_graph(&cfg, g, 0.45)
        });
        rows.push(JsonRow {
            name: name.into(),
            mean_ns: r.mean_ns,
            macs: None,
            mac_rate: None,
            speedup_vs_ref: None,
        });
    }
}

fn bench_runtime(b: &Bencher) {
    let store = ArtifactStore::default_store();
    let Ok(spec) = store.resolve("sf_block_16") else {
        println!("(artifacts missing — skipping PJRT hot-path benches; run `make artifacts`)");
        return;
    };
    let Ok(mut exe) = Executor::new() else {
        println!("(no PJRT client — skipping PJRT hot-path benches)");
        return;
    };
    if exe.load_hlo_text("sf_block", &spec.path).is_err() {
        println!("(PJRT runtime unavailable — skipping PJRT hot-path benches; build with --features pjrt)");
        return;
    }
    let x = TensorBuf::new(vec![8, 16, 16], vec![0.3; 2048]).unwrap();
    let w = TensorBuf::new(vec![8, 8, 3, 3], vec![0.1; 576]).unwrap();
    let bias = TensorBuf::new(vec![8], vec![0.0; 8]).unwrap();
    let skip = TensorBuf::new(vec![8, 16, 16], vec![0.5; 2048]).unwrap();
    b.report("pjrt execute sf_block_16", || {
        exe.run("sf_block", &[x.clone(), w.clone(), bias.clone(), skip.clone()])
            .unwrap()
    });

    if let Ok(spec) = store.resolve("unet_denoise_16") {
        let params = UnetParams::load(store.root(), "unet_params").expect("params");
        exe.load_hlo_text("denoise", &spec.path).expect("compile denoise");
        let img = TensorBuf::new(vec![1, 16, 16], vec![0.1; 256]).unwrap();
        let emb = TensorBuf::new(vec![32], time_embedding(5.0, 32)).unwrap();
        let noise = TensorBuf::zeros(&[1, 16, 16]);
        let mut inputs = vec![
            img,
            emb,
            TensorBuf::scalar(1.01),
            TensorBuf::scalar(0.05),
            TensorBuf::scalar(0.1),
            noise,
        ];
        inputs.extend(params.tensors.iter().cloned());
        let r = b.report("pjrt execute unet_denoise_16 (naive: convert all 39)", || {
            exe.run("denoise", &inputs).unwrap()
        });
        // §Perf variant: params pre-converted once, 6 dynamic tensors/step
        let prepared = exe.prepare(&params.tensors).unwrap();
        let dynamic = inputs[..6].to_vec();
        let r2 = b.report("pjrt execute unet_denoise_16 (prepared params)", || {
            exe.run_prepared("denoise", &dynamic, &prepared).unwrap()
        });
        println!(
            "  -> serving ceiling: naive {:.1} -> prepared {:.1} steps/s/worker ({:+.1}%)",
            1e9 / r.mean_ns,
            1e9 / r2.mean_ns,
            100.0 * (r.mean_ns / r2.mean_ns - 1.0)
        );

        // §Perf L2: the fused 50-step scan artifact — one dispatch for the
        // whole reverse process.
        if let Ok(spec) = store.resolve("unet_denoise_scan50_16") {
            exe.load_hlo_text("scan", &spec.path).expect("compile scan");
            let t = 50usize;
            let mut t_embs = Vec::new();
            let mut coeffs = Vec::new();
            for s in (0..t).rev() {
                t_embs.extend(time_embedding(s as f32, 32));
                coeffs.extend([1.01f32, 0.05, if s > 0 { 0.1 } else { 0.0 }]);
            }
            let dynamic = vec![
                TensorBuf::new(vec![1, 16, 16], vec![0.1; 256]).unwrap(),
                TensorBuf::new(vec![t, 32], t_embs).unwrap(),
                TensorBuf::new(vec![t, 3], coeffs).unwrap(),
                TensorBuf::new(vec![t, 1, 16, 16], vec![0.0; t * 256]).unwrap(),
            ];
            let r3 = b.report("pjrt execute unet_denoise_scan50 (50 steps fused)", || {
                exe.run_prepared("scan", &dynamic, &prepared).unwrap()
            });
            println!(
                "  -> fused per-step: {:.3} ms vs step-at-a-time {:.3} ms (x{:.2})",
                r3.mean_ns / 50.0 / 1e6,
                r2.mean_ns / 1e6,
                r2.mean_ns / (r3.mean_ns / 50.0)
            );
        }
    }
}

/// CI regression gate: map this run's rows onto the shared comparator
/// (`util::bench::check_against_baseline`; >15% drop exits 1, tolerance
/// via `SF_MMCN_BENCH_TOLERANCE` in percent).
fn check_against(rows: &[JsonRow], baseline_path: &str) {
    let current = BenchBaseline {
        provisional: false,
        rows: rows
            .iter()
            .map(|r| BaselineRow {
                name: r.name.clone(),
                mean_ns: Some(r.mean_ns),
                mac_rate: r.mac_rate,
                speedup_vs_ref: r.speedup_vs_ref,
            })
            .collect(),
    };
    check_against_baseline(&current, baseline_path, "hotpath");
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("SF_MMCN_BENCH_QUICK").is_ok();
    let argv: Vec<String> = std::env::args().collect();
    let baseline_path = argv
        .iter()
        .position(|a| a == "--check-against")
        .and_then(|i| argv.get(i + 1).cloned());
    println!(
        "==================== HOT-PATH BENCH ({}) ====================\n",
        if quick { "quick" } else { "full" }
    );
    let mut rows: Vec<JsonRow> = Vec::new();
    let b = Bencher::default();
    bench_unit_group(&b, &mut rows);

    // ISSUE 9 kernel + fused-scan rows (quick included: the fused-scan
    // speedup is the cheapest always-on evidence the resident path is
    // actually faster, not just bit-identical).
    bench_step_kernel(&Bencher::quick(), &mut rows);
    bench_native_scan(&Bencher::quick(), &mut rows);

    // Micro-sim residual pair: fast vs reference (the §Perf acceptance
    // gate: >= 5x on this workload).
    let pair = residual_pair_graph();
    bench_sim_graph(
        &Bencher::quick(),
        Some(&Bencher::quick()),
        "residual_pair_16ch_32",
        &pair,
        1,
        None,
        &mut rows,
    );

    // Micro-sim U-net (the diffusion workload the coordinator co-sims).
    bench_sim_graph(
        &Bencher::quick(),
        Some(&Bencher::quick()),
        "unet16_sim",
        &unet(UnetConfig::default()),
        2,
        Some(UnetConfig::default().time_dim),
        &mut rows,
    );

    if !quick {
        // Full-model cycle-accurate sims (§Perf acceptance gate: >= 10x
        // on ResNet-18 vs the reference path). Single iterations — these
        // execute billions of simulated MACs.
        let one_shot = Bencher {
            warmup: Duration::ZERO,
            measure: Duration::ZERO,
            min_iters: 1,
            max_iters: 1,
        };
        bench_sim_graph(
            &one_shot,
            Some(&one_shot),
            "resnet18_224_sim",
            &resnet18(224, 1000),
            3,
            None,
            &mut rows,
        );
        bench_sim_graph(
            &one_shot,
            None, // reference VGG-16 @224 takes minutes; fast-only trend
            "vgg16_224_sim",
            &vgg16(224, 1000),
            4,
            None,
            &mut rows,
        );
    } else {
        println!("(--quick: skipping full VGG-16 / ResNet-18 micro-sims)");
    }

    bench_analytic(&Bencher::quick(), &mut rows);
    bench_runtime(&Bencher::quick());

    write_json(if quick { "quick" } else { "full" }, &rows);
    if let Some(path) = baseline_path {
        check_against(&rows, &path);
    }
    println!("\nhotpath bench OK");
}
