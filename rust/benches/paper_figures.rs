//! Bench: regenerate Figures 20-25 with shape assertions and timings.
//!
//! Run: `cargo bench --bench paper_figures` (or `make bench`).
//! Output is recorded in EXPERIMENTS.md.

use sf_mmcn::report;
use sf_mmcn::util::bench::Bencher;

fn main() {
    println!("==================== PAPER FIGURES ====================\n");

    // --- Fig 20 -------------------------------------------------------------
    let (text, nu) = report::fig20();
    println!("{text}");
    let m: std::collections::HashMap<usize, f64> = nu.into_iter().collect();
    assert!(m[&8] < m[&4] && m[&8] < m[&2], "8 units beats 2/4 on nu");
    assert!(m[&16] <= m[&8], "16 marginally best (paper's observation)");

    // --- Fig 21 --------------------------------------------------------------
    let (text, (vgg, rn)) = report::fig21();
    println!("{text}");
    assert_eq!(vgg.len(), 13);
    assert_eq!(rn.len(), 17);
    let vgg_first = vgg[0];
    assert!(
        vgg[1..].iter().all(|&u| u > vgg_first),
        "VGG first layer lowest utilization (3-channel input)"
    );
    let rn_best = rn.iter().cloned().fold(0.0, f64::max);
    assert!(rn_best > 0.95, "ResNet residual layers reach ~100%");

    // --- Fig 22 --------------------------------------------------------------
    let (text, s22) = report::fig22();
    println!("{text}");
    assert!(s22.iter().all(|&(n, sf, ca)| sf == 9 && ca == 3 * n));

    // --- Fig 23 -------------------------------------------------------------
    let (text, s23) = report::fig23();
    println!("{text}");
    assert!(s23.iter().all(|&(_, _, so, _, co)| so == 8 && co == 1));

    // --- Fig 24 -------------------------------------------------------------
    let (text, s24) = report::fig24();
    println!("{text}");
    assert!(s24.iter().all(|r| r.3 > 1.0), "SF-MMCN always faster than MMCN");
    assert!(
        s24.last().unwrap().3 > s24.first().unwrap().3,
        "gap grows on the diffusion model"
    );

    // --- Fig 25 --------------------------------------------------------------
    let (text, _series, combined) = report::fig25();
    println!("{text}");
    assert!(combined > 10.0);

    // --- timings ---------------------------------------------------------
    println!("--- harness timings ---");
    let b = Bencher::quick();
    b.report("fig20 (4-point unit sweep, ResNet-18@224)", report::fig20);
    b.report("fig21 (per-layer U_PE, both models @224)", report::fig21);
    b.report("fig22 (first-output sweep)", report::fig22);
    b.report("fig23 (filter-shape sweep)", report::fig23);
    b.report("fig24 (MMCN latency comparison)", report::fig24);
    b.report("fig25 (U-net block throughput)", report::fig25);
    println!("\npaper_figures bench OK");
}
