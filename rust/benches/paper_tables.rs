//! Bench: regenerate Tables I, II, III and the §IV headline ratios, with
//! timing of the underlying full-model analyses.
//!
//! Run: `cargo bench --bench paper_tables` (or `make bench`).
//! Output is recorded in EXPERIMENTS.md.

use sf_mmcn::report;
use sf_mmcn::util::bench::Bencher;

fn main() {
    println!("==================== PAPER TABLES ====================\n");

    // --- Table I ---------------------------------------------------------
    let (text, sim) = report::table1(224);
    println!("{text}");
    // sanity: shapes the paper claims
    let sf = &sim[0].report;
    assert!(sf.core_power_w * 1e3 < 30.0, "SF core power stays ~tens of mW");
    assert!(
        sim[1..]
            .iter()
            .all(|r| sf.gops_per_w > r.report.gops_per_w),
        "SF wins energy efficiency against every simulated baseline"
    );

    // --- Table II ----------------------------------------------------------
    let (text, rows) = report::table2();
    println!("{text}");
    assert!(rows.iter().all(|r| (r.speedup - 8.0 / 3.0).abs() < 1e-9));

    // --- Table III ----------------------------------------------------------
    let (text, rep) = report::table3();
    println!("{text}");
    assert!((0.3..0.6).contains(&rep.area_mm2));

    // --- headline ratios ------------------------------------------------
    let (text, h) = report::headline_ratios(224);
    println!("{text}");
    assert!(h.power_reduction_vs_parallel > 0.6);
    assert!(h.area_reduction_vs_parallel > 0.55);

    // --- timings -----------------------------------------------------------
    println!("--- harness timings (full-model analytic sweeps) ---");
    let b = Bencher::quick();
    b.report("table1(img=224)", || report::table1(224));
    b.report("table2()", report::table2);
    b.report("table3()", report::table3);
    b.report("headline_ratios(224)", || report::headline_ratios(224));
    println!("\npaper_tables bench OK");
}
