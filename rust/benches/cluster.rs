//! Bench: multi-process cluster serving throughput (ISSUE 10).
//!
//! Spawns real `shard-worker` child processes of this crate's own binary
//! and drives the `ClusterFleet` front door over the Unix-socket wire
//! protocol — the full process-supervision path, nothing mocked. Two
//! scenario kinds per process count:
//!
//! * `burst`   — closed-loop saturation: the whole workload submitted at
//!               once, aggregate req/s measured client-side from first
//!               submit to last delivery. This is the near-linear scaling
//!               measurement: with one single-lane session per process,
//!               N processes should approach N x the 1-process rate until
//!               the host runs out of cores.
//! * `nominal` — open-loop at 0.4 x the calibrated 1-process capacity
//!               per process, queue sized to the workload: the cluster
//!               must admit and deliver everything (zero shed).
//!
//! One mixed multi-mode cell rides along (ISSUE 10 satellite): the
//! 2-process nominal scenario under `model_mix = unet:2,resnet18:1,vgg16:1`,
//! exercising all three model kinds across the wire; its per-model rows
//! land in the JSON.
//!
//! Run: `cargo bench --bench cluster` (1/2/4/8 processes) or `-- --quick`
//! (CI profile: 1/2 processes, smaller workloads). Results go to
//! `BENCH_cluster.json` (written before any gate can fire). Always-on
//! gates, quick included:
//!
//! * every nominal cell delivers its whole workload with zero shed and
//!   zero failures;
//! * the 2-process burst rate sustains >= 1.5x the 1-process burst rate
//!   (the scaling floor from the ISSUE 10 acceptance criteria; the
//!   `SF_MMCN_CLUSTER_SCALING_FLOOR` env var overrides the floor for
//!   constrained hosts — CI keeps the 1.5 default and instead retries
//!   the whole bench once to absorb shared-runner noise);
//! * no cell records a failover (no worker process may die under a
//!   clean bench load).

#[cfg(unix)]
mod bench {
    use std::path::Path;
    use std::time::{Duration, Instant};

    use sf_mmcn::config::{ServeBackend, ServeConfig};
    use sf_mmcn::coordinator::{workload, AdmissionError, ClusterFleet, FleetMetrics};

    /// Per-mode slice of a mixed cell (model name, delivered, failed).
    struct ModelRow {
        model: &'static str,
        done: usize,
        failed: usize,
    }

    struct Cell {
        name: String,
        procs: usize,
        scenario: &'static str,
        model_mix: String,
        target_rps: Option<f64>,
        offered: usize,
        delivered: u64,
        failed: u64,
        shed: u64,
        failovers: u64,
        req_per_s: f64,
        scaling_vs_1p: Option<f64>,
        p50_ms: f64,
        p95_ms: f64,
        p99_ms: f64,
        wall_s: f64,
        per_model: Vec<ModelRow>,
    }

    fn json_f64(v: f64) -> String {
        if v.is_finite() {
            format!("{v:.3}")
        } else {
            "null".to_string()
        }
    }

    fn opt_f64(v: Option<f64>) -> String {
        v.map_or("null".to_string(), json_f64)
    }

    fn cluster_cfg(procs: usize, steps: usize, queue_depth: usize) -> ServeConfig {
        ServeConfig {
            steps,
            requests: 0,
            workers: 1,
            max_batch: 2,
            seed: 7,
            artifact: "unet_denoise_16".into(),
            cosim: false,
            fused: false,
            backend: ServeBackend::Native,
            batched: true,
            pipeline: false,
            chunk: 1,
            pooled: true,
            queue_depth,
            priorities: 2,
            shards: 1,
            cluster: procs,
            heartbeat_ms: 10,
            heartbeat_misses: 8,
            ..ServeConfig::default()
        }
    }

    fn exe() -> &'static Path {
        Path::new(env!("CARGO_BIN_EXE_sf-mmcn"))
    }

    /// Drive one cluster cell. `rate` None = closed-loop burst (submit
    /// everything at once); Some = fixed open-loop arrival schedule via
    /// `try_submit` (overload shed, counted, never parked). The req/s
    /// figure is measured client-side from first submit to last
    /// delivery, so worker spawn and drain time never pollute it.
    fn run_cell(
        name: &str,
        procs: usize,
        steps: usize,
        n: usize,
        rate: Option<f64>,
        model_mix: &str,
    ) -> Cell {
        let mut cfg = cluster_cfg(procs, steps, n.max(8));
        cfg.model_mix = model_mix.to_string();
        let fleet = ClusterFleet::start(cfg.clone(), exe())
            .expect("cluster start (spawning shard-worker processes)");
        let reqs = workload(&cfg, cfg.seed, 0..n);
        let t0 = Instant::now();
        let mut tickets = Vec::with_capacity(n);
        let mut shed = 0u64;
        for (i, req) in reqs.into_iter().enumerate() {
            if let Some(rate) = rate {
                // fixed synthetic arrival schedule: request i is due at i/rate
                let due = Duration::from_secs_f64(i as f64 / rate.max(1e-9));
                if let Some(sleep) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
                match fleet.try_submit(req) {
                    Ok(t) => tickets.push(t),
                    Err(AdmissionError::QueueFull) => shed += 1,
                    Err(e) => panic!("unexpected admission error: {e}"),
                }
            } else {
                tickets.push(fleet.submit(req).expect("burst workload admitted"));
            }
        }
        let mut delivered = 0u64;
        let mut failed = 0u64;
        for t in tickets {
            match t.wait() {
                Ok(_) => delivered += 1,
                Err(_) => failed += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let m: FleetMetrics = fleet.shutdown().expect("cluster shutdown");
        let per_model = m
            .per_model
            .iter()
            .filter(|r| r.requests_done + r.requests_failed > 0)
            .map(|r| ModelRow {
                model: r.model.name(),
                done: r.requests_done,
                failed: r.requests_failed,
            })
            .collect();
        let cell = Cell {
            name: name.to_string(),
            procs,
            scenario: if rate.is_some() { "nominal" } else { "burst" },
            model_mix: model_mix.to_string(),
            target_rps: rate,
            offered: n,
            delivered,
            failed,
            shed,
            failovers: m.stats.failovers,
            req_per_s: delivered as f64 / wall.max(1e-9),
            scaling_vs_1p: None,
            p50_ms: m.e2e_latency.p50_us() / 1e3,
            p95_ms: m.e2e_latency.p95_us() / 1e3,
            p99_ms: m.e2e_latency.p99_us() / 1e3,
            wall_s: wall,
            per_model,
        };
        println!(
            "bench cluster::{:<18} {} proc  offered {:>3}  delivered {:>3}  shed {:>3}  \
             {:>8.1} req/s  e2e p50 {:.2} ms  p95 {:.2}  p99 {:.2}  wall {:.3}s",
            cell.name,
            cell.procs,
            cell.offered,
            cell.delivered,
            cell.shed,
            cell.req_per_s,
            cell.p50_ms,
            cell.p95_ms,
            cell.p99_ms,
            cell.wall_s,
        );
        cell
    }

    /// `BENCH_cluster.json`: the per-cell scaling artifact CI uploads
    /// (written before any gate can fire).
    fn write_json(mode: &str, capacity_1p: f64, cells: &[Cell]) {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"bench\": \"cluster\",\n");
        s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
        s.push_str(&format!(
            "  \"capacity_1p_rps\": {},\n",
            json_f64(capacity_1p)
        ));
        s.push_str("  \"results\": [\n");
        for (i, c) in cells.iter().enumerate() {
            s.push_str("    {");
            s.push_str(&format!("\"name\": \"{}\", ", c.name));
            s.push_str(&format!("\"procs\": {}, ", c.procs));
            s.push_str(&format!("\"scenario\": \"{}\", ", c.scenario));
            s.push_str(&format!("\"model_mix\": \"{}\", ", c.model_mix));
            s.push_str(&format!("\"target_rps\": {}, ", opt_f64(c.target_rps)));
            s.push_str(&format!("\"offered\": {}, ", c.offered));
            s.push_str(&format!("\"delivered\": {}, ", c.delivered));
            s.push_str(&format!("\"failed\": {}, ", c.failed));
            s.push_str(&format!("\"shed\": {}, ", c.shed));
            s.push_str(&format!("\"failovers\": {}, ", c.failovers));
            s.push_str(&format!("\"req_per_s\": {}, ", json_f64(c.req_per_s)));
            s.push_str(&format!(
                "\"scaling_vs_1p\": {}, ",
                opt_f64(c.scaling_vs_1p)
            ));
            s.push_str(&format!("\"p50_ms\": {}, ", json_f64(c.p50_ms)));
            s.push_str(&format!("\"p95_ms\": {}, ", json_f64(c.p95_ms)));
            s.push_str(&format!("\"p99_ms\": {}, ", json_f64(c.p99_ms)));
            s.push_str(&format!("\"wall_s\": {}, ", json_f64(c.wall_s)));
            s.push_str("\"per_model\": [");
            for (j, r) in c.per_model.iter().enumerate() {
                s.push_str(&format!(
                    "{{\"model\": \"{}\", \"requests_done\": {}, \"requests_failed\": {}}}",
                    r.model, r.done, r.failed
                ));
                if j + 1 < c.per_model.len() {
                    s.push_str(", ");
                }
            }
            s.push_str("]}");
            if i + 1 < cells.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        match std::fs::write("BENCH_cluster.json", &s) {
            Ok(()) => println!("\nwrote BENCH_cluster.json ({} cells)", cells.len()),
            Err(e) => println!("\nWARNING: could not write BENCH_cluster.json: {e}"),
        }
    }

    /// Always-on gates (quick included). Returns true when all pass.
    fn check_gates(cells: &[Cell]) -> bool {
        let mut ok = true;
        for c in cells {
            if c.scenario == "nominal"
                && (c.shed > 0 || c.failed > 0 || c.delivered != c.offered as u64)
            {
                println!(
                    "CLUSTER GATE FAILED: {} delivered {}/{} with {} shed / {} failed — \
                     nominal cells must admit and deliver the whole workload",
                    c.name, c.delivered, c.offered, c.shed, c.failed
                );
                ok = false;
            }
            if c.failovers > 0 {
                println!(
                    "CLUSTER GATE FAILED: {} recorded {} failovers — no worker process \
                     may die under a clean bench load",
                    c.name, c.failovers
                );
                ok = false;
            }
        }
        let burst_rate = |procs: usize| -> Option<f64> {
            cells
                .iter()
                .find(|c| c.scenario == "burst" && c.procs == procs)
                .map(|c| c.req_per_s)
        };
        // The acceptance floor is 1.5x; SF_MMCN_CLUSTER_SCALING_FLOOR
        // lowers (or raises) it for hosts where the measurement itself
        // is unreliable — e.g. an oversubscribed 2-core box that cannot
        // run two worker processes concurrently at all.
        let floor = std::env::var("SF_MMCN_CLUSTER_SCALING_FLOOR")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(1.5);
        if let (Some(r1), Some(r2)) = (burst_rate(1), burst_rate(2)) {
            let scaling = r2 / r1.max(1e-9);
            if scaling < floor {
                println!(
                    "CLUSTER GATE FAILED: 2-process aggregate {r2:.1} req/s is only \
                     x{scaling:.2} the 1-process {r1:.1} req/s — the scaling floor is x{floor}"
                );
                ok = false;
            } else {
                println!("cluster scaling OK: 2 processes sustain x{scaling:.2} of 1 process");
            }
        }
        if ok {
            println!("cluster gates OK: {} cells", cells.len());
        }
        ok
    }

    pub fn main() {
        let args: Vec<String> = std::env::args().collect();
        let quick = args.iter().any(|a| a == "--quick")
            || std::env::var("SF_MMCN_BENCH_QUICK").is_ok();
        let (steps, per_proc) = if quick { (2, 16) } else { (4, 24) };
        let proc_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };

        println!(
            "==================== CLUSTER BENCH ({}) ====================\n\
             shard-worker processes over the Unix-socket wire protocol, native \
             surrogate backend, 1 lane per process, {per_proc} requests/process x \
             {steps} steps\n",
            if quick { "quick" } else { "full" }
        );

        let mut cells = Vec::new();

        // closed-loop burst cells: the near-linear scaling measurement
        for &procs in proc_counts {
            let n = per_proc * procs;
            cells.push(run_cell(
                &format!("burst_{procs}p"),
                procs,
                steps,
                n,
                None,
                "unet",
            ));
        }
        let capacity_1p = cells[0].req_per_s.max(1e-9);
        for c in cells.iter_mut() {
            c.scaling_vs_1p = Some(c.req_per_s / capacity_1p);
        }

        // open-loop nominal cells: 0.4x the calibrated 1-process
        // capacity per process; the cluster must keep up without
        // shedding
        for &procs in proc_counts {
            let n = per_proc * procs;
            let rate = 0.4 * capacity_1p * procs as f64;
            cells.push(run_cell(
                &format!("nominal_{procs}p"),
                procs,
                steps,
                n,
                Some(rate),
                "unet",
            ));
        }

        // the mixed multi-mode cell: all three model kinds on the wire
        // at the 2-process nominal operating point
        cells.push(run_cell(
            "nominal_2p_mixed",
            2,
            steps,
            per_proc * 2,
            Some(0.4 * capacity_1p * 2.0),
            "unet:2,resnet18:1,vgg16:1",
        ));

        write_json(if quick { "quick" } else { "full" }, capacity_1p, &cells);
        if !check_gates(&cells) {
            std::process::exit(1);
        }
        println!("\ncluster bench OK");
    }
}

#[cfg(unix)]
fn main() {
    bench::main();
}

#[cfg(not(unix))]
fn main() {
    println!("cluster bench requires Unix domain sockets; skipping on this platform");
}
