//! End-to-end validation driver (EXPERIMENTS.md §E2E): diffusion-model
//! *serving* on the full three-layer stack.
//!
//! * L1/L2: the trained U-net (Pallas SF kernels) AOT-compiled to
//!   `artifacts/unet_denoise_16.hlo.txt` at build time.
//! * L3: the rust coordinator — request queue, batcher, worker lanes, each
//!   executing the DDPM reverse loop through PJRT; the DDPM schedule and
//!   time embeddings are computed in rust.
//! * Co-simulation: the SF-MMCN accelerator model runs the same U-net
//!   workload, reporting the cycles/power the paper's chip would spend.
//!
//! Run: `cargo run --release --example diffusion_denoise` (after
//! `make artifacts`). Flags: --requests N --steps N --workers N

use anyhow::Result;

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{workload, DiffusionServer};
use sf_mmcn::runtime::ArtifactStore;
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::cli::Args;

/// Render a 16x16 image as ASCII (the "generated figure").
fn ascii_image(data: &[f32], w: usize) -> String {
    let ramp: &[u8] = b" .:-=+*#%@";
    let lo = data.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-6);
    let mut out = String::new();
    for (i, v) in data.iter().enumerate() {
        let t = ((v - lo) / span * (ramp.len() - 1) as f32).round() as usize;
        out.push(ramp[t.min(ramp.len() - 1)] as char);
        if (i + 1) % w == 0 {
            out.push('\n');
        }
    }
    out
}

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let mut cfg = ServeConfig {
        requests: args.get_usize("requests", 8)?,
        steps: args.get_usize("steps", 50)?,
        workers: args.get_usize("workers", 2)?,
        ..ServeConfig::default()
    };
    // --native: run offline on the host-CPU surrogate (no artifacts),
    // with the batched + pipelined request path of ISSUE 3 and the
    // pooled zero-allocation hot path of ISSUE 4 (pooled by default;
    // see `sf-mmcn serve --no-pool` for the allocating baseline).
    if args.flag("native") {
        cfg.backend = ServeBackend::Native;
        cfg.batched = true;
    }

    println!("=== SF-MMCN end-to-end: diffusion de-noise serving ===");
    println!(
        "workload: {} requests x {} DDPM steps, {} workers, {} backend{}\n",
        cfg.requests,
        cfg.steps,
        cfg.workers,
        cfg.backend.name(),
        if cfg.batched {
            " (batched + pipelined)"
        } else {
            ", batch=1 per execution (the chip's real-time constraint, §III.D)"
        }
    );

    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store)?;
    let requests = workload(&cfg, cfg.seed, 0..cfg.requests);
    let (results, metrics) = server.serve(requests)?;

    println!("{}", metrics.render());

    // functional sanity: outputs must be bounded (the trained de-noiser
    // contracts noise instead of amplifying it)
    let mut worst: f32 = 0.0;
    for r in &results {
        let m = r.image.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        worst = worst.max(m);
    }
    println!("max |pixel| over all generated images: {worst:.3}");
    assert!(
        worst < 20.0,
        "denoise loop diverged — retrain artifacts (make clean artifacts)"
    );

    if let Some(rep) = metrics.sim_report(&CAL_40NM, 8) {
        println!(
            "\nco-simulated SF-MMCN accelerator for the same workload:\n\
             {} cycles  {:.2} ms @400 MHz  {:.1} mW core  {:.1} GOPs  U_PE {:.1}%\n\
             energy per image: {:.1} uJ",
            rep.cycles,
            rep.runtime_s * 1e3,
            rep.core_power_w * 1e3,
            rep.gops,
            rep.u_pe * 100.0,
            rep.core_energy_j * 1e6 / metrics.requests_done.max(1) as f64,
        );
    }

    if let Some(r) = results.iter().find(|r| r.id == 0) {
        println!("\ngenerated sample (request 0, {} steps):", r.steps);
        println!("{}", ascii_image(&r.image.data, 16));
    }

    println!("diffusion_denoise OK");
    Ok(())
}
