//! Design-space exploration: the Fig-20 experiment generalized — sweep
//! the number of SF-MMCN units across all three models and report
//! latency / power / efficiency-factor trade-offs, in parallel on the
//! from-scratch thread pool.
//!
//! Run: `cargo run --release --example design_space`

use anyhow::Result;

use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::models::{resnet18, unet, vgg16, ModelGraph, UnetConfig};
use sf_mmcn::sim::array::AcceleratorConfig;
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::pool::ThreadPool;

const REF_PES: f64 = 72.0;

fn main() -> Result<()> {
    println!("=== SF-MMCN design-space sweep (units x models) ===\n");
    let models: Vec<(&str, ModelGraph)> = vec![
        ("vgg16@224", vgg16(224, 1000)),
        ("resnet18@224", resnet18(224, 1000)),
        ("unet16", unet(UnetConfig::default())),
    ];
    let unit_counts = [1usize, 2, 4, 8, 16, 32];

    // Build the work list: (model name, graph clone, units)
    let mut work = Vec::new();
    for (name, g) in &models {
        for &u in &unit_counts {
            work.push((name.to_string(), g.clone(), u));
        }
    }

    let pool = ThreadPool::new(std::thread::available_parallelism()?.get().min(8));
    let results = pool.map(work, |(name, g, units)| {
        let cfg = AcceleratorConfig::with_units(units);
        let a = analyze_graph(&cfg, &g, 0.45);
        let rep = CAL_40NM.report(&a.totals, units as u64);
        // fixed-reference nu (the Fig-20 design-selection metric)
        let u_ref =
            a.totals.pe.active_cycles as f64 / (a.totals.cycles as f64 * REF_PES);
        let nu_ref = rep.core_power_w / u_ref;
        (name, units, a.total_cycles(), rep, nu_ref)
    });

    println!(
        "{:<14} {:>6} {:>13} {:>9} {:>9} {:>8} {:>9} {:>10}",
        "model", "units", "cycles", "ms@400", "mW", "GOPs", "U_PE", "nu(72ref)"
    );
    let mut last_model = String::new();
    for (name, units, cycles, rep, nu_ref) in &results {
        if *name != last_model {
            println!();
            last_model = name.clone();
        }
        println!(
            "{:<14} {:>6} {:>13} {:>9.2} {:>9.1} {:>8.1} {:>8.1}% {:>10.4}",
            name,
            units,
            cycles,
            rep.runtime_s * 1e3,
            rep.core_power_w * 1e3,
            rep.gops,
            rep.u_pe * 100.0,
            nu_ref
        );
    }

    // The paper's conclusion: 8 units is the knee.
    for (name, _g) in &models {
        let series: Vec<&(String, usize, u64, _, f64)> = results
            .iter()
            .filter(|r| &r.0 == name)
            .collect();
        let nu8 = series.iter().find(|r| r.1 == 8).unwrap().4;
        let nu4 = series.iter().find(|r| r.1 == 4).unwrap().4;
        let nu16 = series.iter().find(|r| r.1 == 16).unwrap().4;
        assert!(nu8 < nu4, "{name}: 8 units must beat 4 on nu");
        assert!(
            (nu4 - nu8) > (nu8 - nu16),
            "{name}: diminishing returns past 8 units"
        );
    }
    println!("\nknee at 8 units on every model (the paper's shipped config)");
    println!("design_space OK");
    Ok(())
}
