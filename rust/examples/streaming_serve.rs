//! Streaming serving session walkthrough (ISSUE 5): the long-running
//! API behind the "millions of users" north star.
//!
//! A [`ServerHandle`] owns the worker lanes for the life of the session.
//! This example runs entirely offline on the native surrogate backend:
//!
//! 1. `start()` the session, then trickle requests in on a schedule
//!    (mixed priorities, one with a tight deadline) — the Server Flow
//!    shape: work streams through a fixed engine instead of being
//!    pre-staged (paper §III).
//! 2. Shed overload with `try_submit` against the bounded queue.
//! 3. Read `metrics_snapshot()` mid-flight — live queue depth,
//!    admission counters, and fixed-memory latency percentiles.
//! 4. `shutdown()` gracefully: admission closes, every admitted ticket
//!    resolves, lanes join.
//!
//! Run: `cargo run --release --example streaming_serve`

use std::time::Duration;

use anyhow::Result;

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{workload, AdmissionError, DiffusionServer};
use sf_mmcn::runtime::ArtifactStore;

fn main() -> Result<()> {
    let cfg = ServeConfig {
        steps: 6,
        requests: 12,
        workers: 2,
        max_batch: 4,
        backend: ServeBackend::Native,
        batched: true,
        cosim: false,
        queue_depth: 8,
        ..ServeConfig::default()
    };
    println!("=== SF-MMCN streaming serving session ===");
    println!(
        "{} workers, max_batch {}, bounded queue depth {}, native backend\n",
        cfg.workers, cfg.max_batch, cfg.queue_depth
    );

    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store)?;
    let handle = server.start();

    // Trickle a deterministic workload in: every third request is
    // low-priority, and one carries a deadline it cannot meet (it will
    // be expired in the queue or rejected at admission, never executed).
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for (i, mut req) in workload(&cfg, cfg.seed, 0..cfg.requests)
        .into_iter()
        .enumerate()
    {
        if i % 3 == 2 {
            req.set_priority(2); // batch-job lane
        }
        if i == 5 {
            req.set_deadline(Some(Duration::from_nanos(1))); // unmeetable
        }
        match handle.try_submit(req) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => shed += 1,
            Err(e) => println!("request {i} not admitted: {e}"),
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    let snap = handle.metrics_snapshot();
    println!("mid-session snapshot (live, lanes undisturbed):");
    println!(
        "  queue depth {}  admitted {}  rejected {}  expired {}  done {}",
        snap.admission.queue_depth,
        snap.admission.admitted,
        snap.admission.rejected_total(),
        snap.admission.expired,
        snap.requests_done,
    );

    // Every admitted ticket resolves — results, or an expiry error for
    // the doomed request.
    let (mut ok, mut expired) = (0usize, 0usize);
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                ok += 1;
                if r.id == 0 {
                    let mean: f32 = r.image.data.iter().sum::<f32>() / r.image.len() as f32;
                    println!(
                        "  first result: id {} shape {:?} mean {mean:.4} \
                         (service {:.2} ms)",
                        r.id,
                        r.image.shape,
                        r.latency.as_secs_f64() * 1e3
                    );
                }
            }
            Err(e) => {
                expired += 1;
                println!("  ticket resolved with error: {e}");
            }
        }
    }

    let metrics = handle.shutdown()?;
    println!("\nfinal session metrics:\n{}", metrics.render());
    println!(
        "summary: {ok} served, {expired} expired/failed, {shed} shed at the \
         bounded queue"
    );
    println!("streaming_serve OK");
    Ok(())
}
