//! ResNet-18 inference on the cycle-accurate micro simulator — the
//! paper's *parallel/residual* evaluation scenario (Fig 21b), plus the
//! MMCN-baseline latency comparison (Fig 24) on the same network.
//!
//! Run: `cargo run --release --example resnet_inference` (no artifacts
//! needed — this exercises the simulator with real fixed-point numerics).

use anyhow::Result;

use sf_mmcn::baselines::mmcn;
use sf_mmcn::compiler::analyze_graph;
use sf_mmcn::models::resnet18;
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::cli::Args;
use sf_mmcn::util::{Rng, Tensor};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let img = args.get_usize("img", 64)?;
    let seed = args.get_u64("seed", 3)?;

    println!("=== ResNet-18 @ {img} on the SF-MMCN micro simulator ===\n");
    let g = resnet18(img, 10);
    println!(
        "{} nodes, {} residual-fused convs, {:.1} M MACs",
        g.nodes.len(),
        g.parallel_nodes(),
        g.total_macs() as f64 / 1e6
    );

    let ws = WeightStore::random(&g, seed);
    let mut rng = Rng::new(seed ^ 0xF00D);
    let x = Tensor::from_fn(&[3, img, img], |_| rng.normal() * 0.4);

    let mut acc = Accelerator::new(AcceleratorConfig::default());
    let run = acc.run_graph(&g, &x, &ws, None)?;

    println!("\nper-layer (conv layers only):");
    println!(
        "{:<6} {:<44} {:>10} {:>7}",
        "node", "layer", "cycles", "U_PE"
    );
    for l in run.layers.iter().filter(|l| l.label.starts_with("conv")) {
        println!(
            "{:<6} {:<44} {:>10} {:>6.1}%",
            l.node_idx,
            l.label,
            l.cycles,
            l.u_pe * 100.0
        );
    }

    // classification head output
    let logits = &run.output;
    let pred = logits
        .data()
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    println!("\nlogits shape {:?}, argmax class {pred}", logits.shape());

    let rep = CAL_40NM.report(&run.totals, 8);
    println!(
        "\nSF-MMCN: {} cycles  {:.3} ms @400 MHz  {:.1} mW core  {:.1} GOPs  \
         U_PE {:.1}%  nu {:.4}",
        run.total_cycles(),
        rep.runtime_s * 1e3,
        rep.core_power_w * 1e3,
        rep.gops,
        rep.u_pe * 100.0,
        rep.nu
    );

    // validate the micro-sim against the analytic model (counts must match)
    let ana = analyze_graph(&AcceleratorConfig::default(), &g, 0.0);
    println!(
        "analytic model: {} cycles ({} micro-sim; models agree on mapping, \
         gating differs only through real activation sparsity)",
        ana.total_cycles(),
        run.total_cycles()
    );
    assert_eq!(
        ana.total_cycles(),
        run.total_cycles(),
        "closed-form schedule must match the micro simulator"
    );

    // MMCN baseline: the series strategy pays extra passes for every block
    let mm = mmcn::analyze_graph(&g, 0.0);
    println!(
        "\nMMCN [24] baseline: {} cycles -> SF-MMCN speedup x{:.2} \
         (residual blocks ride PE_9 instead of extra passes)",
        mm.counts.cycles,
        mm.counts.cycles as f64 / run.total_cycles() as f64
    );

    println!("\nresnet_inference OK");
    Ok(())
}
