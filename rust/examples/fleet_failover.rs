//! Fault-tolerant sharded serving walkthrough (ISSUE 6): the
//! [`ShardFleet`] front door, deterministic failover, and the injectable
//! fault plane.
//!
//! Runs entirely offline on the native surrogate backend, in two acts:
//!
//! 1. **Failover** — a two-shard fleet serves a workload while the fault
//!    plane kills shard 0 as it claims its third request (`kill:0:2`).
//!    The monitor detects the death (lost tickets, backstopped by missed
//!    heartbeats), re-admits the undelivered work onto the survivor, and
//!    every ticket still resolves. Because execution is a pure function
//!    of `(seed, steps)`, the recovered images are bit-identical to a
//!    no-fault run — the example checks this against a plain
//!    single-session baseline.
//! 2. **Preemption** — a fresh fleet receives a preemption notice for
//!    shard 0 mid-workload: the shard drains (nothing requeued, nothing
//!    re-executed) and parks as `Drained` while the survivor keeps
//!    serving.
//!
//! Run: `cargo run --release --example fleet_failover`

use std::time::{Duration, Instant};

use anyhow::Result;

use sf_mmcn::config::{ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{workload, DiffusionServer, ShardFleet, ShardState};
use sf_mmcn::runtime::ArtifactStore;

fn fleet_cfg() -> ServeConfig {
    ServeConfig {
        steps: 4,
        requests: 12,
        workers: 1,
        max_batch: 2,
        backend: ServeBackend::Native,
        batched: true,
        pipeline: false,
        chunk: 1, // per-step dispatches: the heartbeat gap is one step
        cosim: false,
        queue_depth: 32,
        shards: 2,
        ..ServeConfig::default()
    }
}

fn main() -> Result<()> {
    let cfg = fleet_cfg();
    let store = ArtifactStore::default_store();
    println!("=== SF-MMCN fault-tolerant sharded serving ===");
    println!(
        "{} shards x {} worker(s), heartbeat {} ms x {} misses\n",
        cfg.shards, cfg.workers, cfg.heartbeat_ms, cfg.heartbeat_misses
    );

    // The no-fault reference: the same workload through one plain session.
    let mut solo = cfg.clone();
    solo.shards = 1;
    let server = DiffusionServer::new(solo, &store)?;
    let (mut want, _) = server.serve(workload(&cfg, cfg.seed, 0..cfg.requests))?;
    want.sort_by_key(|r| r.id);

    // ---- act 1: a seeded kill, failover, bit-identical recovery ----
    let mut faulty = cfg.clone();
    faulty.fault_spec = "kill:0:2".into(); // shard 0 dies claiming request #3
    println!("act 1: fault plane '{}' armed", faulty.fault_spec);
    let fleet = ShardFleet::start(faulty, &store)?;
    let tickets: Vec<_> = workload(&cfg, cfg.seed, 0..cfg.requests)
        .into_iter()
        .map(|r| fleet.submit(r).expect("fleet admits the workload"))
        .collect();
    let mut got: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().expect("every ticket resolves despite the kill"))
        .collect();
    got.sort_by_key(|r| r.id);
    let identical = got
        .iter()
        .zip(&want)
        .all(|(g, w)| g.id == w.id && g.image.data == w.image.data);
    let m = fleet.shutdown()?;
    println!(
        "  delivered {}/{} after {} failover(s), {} request(s) requeued",
        m.stats.delivered, cfg.requests, m.stats.failovers, m.stats.requeued
    );
    println!(
        "  recovery bit-identical to the no-fault run: {}",
        if identical { "YES" } else { "NO (bug!)" }
    );
    println!("{}", m.render());

    // ---- act 2: preemption notice, graceful drain ----
    println!("\nact 2: preemption notice for shard 0 mid-workload");
    let fleet = ShardFleet::start(cfg.clone(), &store)?;
    let tickets: Vec<_> = workload(&cfg, cfg.seed, 0..cfg.requests)
        .into_iter()
        .map(|r| fleet.submit(r).expect("fleet admits the workload"))
        .collect();
    fleet.begin_preempt(0)?;
    for t in tickets {
        t.wait().expect("draining resolves every admitted ticket");
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while fleet.shard_states()[0] != ShardState::Drained && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    println!("  shard states after drain: {:?}", fleet.shard_states());
    let m = fleet.shutdown()?;
    println!(
        "  delivered {}/{} with {} failovers and {} requeues (drain loses nothing)",
        m.stats.delivered, cfg.requests, m.stats.failovers, m.stats.requeued
    );
    println!("\nfleet_failover OK");
    Ok(())
}
