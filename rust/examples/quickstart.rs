//! Quickstart: one server-flow conv+residual block, three ways.
//!
//! 1. **Micro simulator** — cycle-accurate, 16-bit fixed point (the
//!    silicon datapath): numerics + cycles + energy.
//! 2. **PJRT artifact** — the same block AOT-lowered from the Pallas
//!    kernel (`artifacts/sf_block_16.hlo.txt`), executed from rust.
//! 3. **Cross-check** — the two must agree to quantization tolerance,
//!    proving L1 (kernel), L2 (lowering) and L3 (simulator) implement the
//!    same server-flow semantics.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use anyhow::Result;

use sf_mmcn::models::graph::{Act, GraphBuilder, Layer, Residual, TensorShape};
use sf_mmcn::runtime::{ArtifactStore, Executor, TensorBuf};
use sf_mmcn::sim::array::{Accelerator, AcceleratorConfig, WeightStore};
use sf_mmcn::sim::energy::CAL_40NM;
use sf_mmcn::util::{Rng, Tensor};

const C: usize = 8;
const HW: usize = 16;

fn main() -> Result<()> {
    println!("=== SF-MMCN quickstart: fused conv3x3 + residual skip ===\n");

    // ---- inputs (deterministic) ----------------------------------------
    let mut rng = Rng::new(2024);
    let x = Tensor::from_fn(&[C, HW, HW], |_| rng.normal() * 0.3);
    let w = Tensor::from_fn(&[C, C, 3, 3], |_| rng.normal() * 0.15);

    // ---- 1) micro simulator --------------------------------------------
    // Two-node graph: node 0 is the skip *producer* (identity delta
    // kernel, so its output equals the quantized input) and node 1 is the
    // SF block under test — conv(x, w) with the skip served by PE_9.
    let mut b = GraphBuilder::new("quickstart", TensorShape::new(C, HW, HW));
    b.add(Layer::Conv {
        c_in: C,
        c_out: C,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::None,
        time_dense: None,
    })?;
    b.add(Layer::Conv {
        c_in: C,
        c_out: C,
        k: 3,
        stride: 1,
        pad: 1,
        act: Act::None,
        residual: Residual::Identity { from: 0 },
        time_dense: None,
    })?;
    let g = b.build();

    let mut ws = WeightStore::random(&g, 1);
    let delta = Tensor::from_fn(&[C, C, 3, 3], |idx| {
        if idx[0] == idx[1] && idx[2] == 1 && idx[3] == 1 {
            1.0
        } else {
            0.0
        }
    });
    ws.per_node[0].as_mut().unwrap().w = delta;
    ws.per_node[0].as_mut().unwrap().bias = vec![0.0; C];
    ws.per_node[1].as_mut().unwrap().w = w.clone();
    ws.per_node[1].as_mut().unwrap().bias = vec![0.0; C];
    // the store caches quantized taps per node (§Perf, PR 1); drop any
    // cached state after editing weights in place
    ws.invalidate_quant();

    let mut acc = Accelerator::new(AcceleratorConfig::default());
    let run = acc.run_graph(&g, &x, &ws, None)?;
    println!("micro-sim: {} total cycles", run.total_cycles());
    for l in &run.layers {
        println!(
            "  node {}: {:<38} {:>8} cycles  U_PE {:>5.1}%",
            l.node_idx,
            l.label,
            l.cycles,
            l.u_pe * 100.0
        );
    }
    let rep = CAL_40NM.report(&run.totals, 8);
    println!(
        "  energy: {:.2} nJ core  ({:.2} mW at sustained rate)\n",
        rep.core_energy_j * 1e9,
        rep.core_power_w * 1e3
    );

    // ---- 2) PJRT artifact ------------------------------------------------
    // The artifact computes conv(x, w) + b + skip; feed skip = x so it
    // matches the graph above (node 0 passes x through).
    let store = ArtifactStore::default_store();
    let spec = store.resolve("sf_block_16")?;
    let mut exe = Executor::new()?;
    exe.load_hlo_text("sf_block", &spec.path)?;
    println!("PJRT: loaded {} on {}", spec.name, exe.platform());

    let xs = TensorBuf::new(vec![C, HW, HW], x.data().to_vec())?;
    let wb = TensorBuf::new(vec![C, C, 3, 3], w.data().to_vec())?;
    let bias = TensorBuf::new(vec![C], vec![0.0; C])?;
    let skipb = TensorBuf::new(vec![C, HW, HW], x.data().to_vec())?;
    let out = exe.run("sf_block", &[xs, wb, bias, skipb])?;
    let pjrt_out = Tensor::new(&[C, HW, HW], out[0].data.clone())?;
    println!("  output shape {:?}\n", out[0].shape);

    // ---- 3) cross-check ---------------------------------------------------
    let diff = run.output.max_abs_diff(&pjrt_out)?;
    println!("max |sim - pjrt| = {diff:.4}  (Q8.8 quantization budget: < 0.15)");
    assert!(
        diff < 0.15,
        "fixed-point simulator and float PJRT artifact disagree: {diff}"
    );
    println!("\nquickstart OK — all three layers agree on the server-flow block");
    Ok(())
}
