//! Multi-mode serving walkthrough (ISSUE 7): one session serving the
//! paper's three networks — U-net denoise plus ResNet-18 and VGG-16
//! classification — from one queue. Batches never mix models, every
//! result is a pure function of `(model, seed, steps)`, and with
//! co-simulation on the session prices each mode's share of the
//! accelerator separately (the paper's multi-mode CNN claim, §IV).
//!
//! Run: `cargo run --release --example multimode_serve` (offline, native
//! surrogate backend — no artifacts or PJRT needed).

use anyhow::Result;

use sf_mmcn::config::{ModelChoice, ServeBackend, ServeConfig};
use sf_mmcn::coordinator::{workload, ClassifyRequest, DiffusionServer};
use sf_mmcn::runtime::ArtifactStore;
use sf_mmcn::sim::energy::CAL_40NM;

fn main() -> Result<()> {
    let cfg = ServeConfig {
        steps: 4,
        requests: 12,
        workers: 2,
        max_batch: 4,
        backend: ServeBackend::Native,
        batched: true,
        cosim: true,
        model_mix: "unet:2,resnet18:1,vgg16:1".into(),
        ..ServeConfig::default()
    };
    println!("=== SF-MMCN multi-mode serving (one engine, three networks) ===");
    println!(
        "model mix {}  ({} requests, {} workers, max_batch {})\n",
        cfg.model_mix, cfg.requests, cfg.workers, cfg.max_batch
    );

    let store = ArtifactStore::default_store();
    let server = DiffusionServer::new(cfg.clone(), &store)?;

    // The mixed closed-loop workload: the mix pattern decides each
    // request's model; seeds stay a pure function of the request id, so
    // any request replays bit-identically on its own.
    let reqs = workload(&cfg, cfg.seed, 0..cfg.requests);
    let (results, metrics) = server.serve(reqs)?;

    println!("first results off the shared queue:");
    for r in results.iter().take(4) {
        match r.model {
            ModelChoice::Unet => println!(
                "  id {}: unet denoise, {} steps, image {:?}",
                r.id, r.steps, r.image.shape
            ),
            m => {
                let (class, logit) = r
                    .image
                    .data
                    .iter()
                    .enumerate()
                    .fold((0usize, f32::MIN), |best, (k, &v)| {
                        if v > best.1 {
                            (k, v)
                        } else {
                            best
                        }
                    });
                println!(
                    "  id {}: {} classification -> class {class} (logit {logit:.3})",
                    r.id,
                    m.name()
                );
            }
        }
    }

    // Classification also goes through the front door explicitly — same
    // session, same admission queue, same batcher.
    let one = vec![ClassifyRequest::new(99, 1234, ModelChoice::Resnet18)];
    let (one, _) = server.serve(one)?;
    println!(
        "  explicit resnet18 request: {} logits\n",
        one[0].image.len()
    );

    println!("session metrics:\n{}", metrics.render());

    // Per-mode accelerator figures from the co-simulation: each mode's
    // share of the work priced separately on the 40 nm calibration —
    // cycles, GOPs, and the paper's area-efficiency FoM (GOPs/mm2).
    println!("co-simulated per-mode accelerator figures (8 SF units, 40 nm):");
    for row in metrics.per_model.iter().filter(|r| r.sim_counts.is_some()) {
        if let Some(rep) = row.sim_report(&CAL_40NM, 8) {
            println!(
                "  {:<9} {:>12} cycles  {:>8.1} GOPs  {:>7.1} GOPs/mm2  U_PE {:.1}%",
                row.model.name(),
                rep.cycles,
                rep.gops,
                rep.gops_per_mm2,
                rep.u_pe * 100.0
            );
        }
    }
    println!("\nmultimode_serve OK");
    Ok(())
}
