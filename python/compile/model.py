"""L2: the diffusion U-net and SF blocks in JAX, calling the L1 kernels.

The graph structure mirrors `rust/src/models/unet.rs` node for node: every
U-net block is conv1 (+time dense on "PE_9") then conv2 (+block skip) —
the two SF parallel modes. Parameters are created deterministically and
exported in a canonical flat order so the rust runtime can stream them
from `artifacts/unet_params.bin` (see aot.py).

Everything here is build-time only: `aot.py` lowers these functions to
HLO text once; the rust coordinator never imports python.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import pool, ref, sf_conv


@dataclass(frozen=True)
class UnetCfg:
    """Mirror of rust `UnetConfig` (keep in sync)."""

    img_channels: int = 1
    img: int = 16
    base_c: int = 16
    levels: int = 2
    time_dim: int = 32


def time_embedding(t, dim):
    """Sinusoidal time embedding for scalar timestep `t` (float)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = t * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _conv_init(key, o, c, k=3):
    wkey, bkey = jax.random.split(key)
    scale = (2.0 / (c * k * k)) ** 0.5
    return (
        jax.random.normal(wkey, (o, c, k, k)) * scale,
        jax.random.normal(bkey, (o,)) * 0.01,
    )


def _block_param_names(tag, c_in, c_out):
    names = [f"{tag}.w1", f"{tag}.b1", f"{tag}.wt", f"{tag}.w2", f"{tag}.b2"]
    if c_in != c_out:
        names.append(f"{tag}.wres")
    return names


def init_params(cfg: UnetCfg, seed: int = 0):
    """Deterministic parameter dict, keyed by canonical names."""
    key = jax.random.PRNGKey(seed)
    params = {}

    def nk():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def block(tag, c_in, c_out):
        w1, b1 = _conv_init(nk(), c_out, c_in)
        params[f"{tag}.w1"] = w1
        params[f"{tag}.b1"] = b1
        params[f"{tag}.wt"] = (
            jax.random.normal(nk(), (c_out, cfg.time_dim))
            * (2.0 / cfg.time_dim) ** 0.5
        )
        w2, b2 = _conv_init(nk(), c_out, c_out)
        params[f"{tag}.w2"] = w2
        params[f"{tag}.b2"] = b2
        if c_in != c_out:
            params[f"{tag}.wres"] = (
                jax.random.normal(nk(), (c_out, c_in)) * (2.0 / c_in) ** 0.5
            )

    w, b = _conv_init(nk(), cfg.base_c, cfg.img_channels)
    params["stem.w"], params["stem.b"] = w, b

    c = cfg.base_c
    for lvl in range(cfg.levels):
        c_out = cfg.base_c << lvl
        block(f"enc{lvl}", c, c_out)
        c = c_out
    block("mid", c, cfg.base_c << cfg.levels)
    c = cfg.base_c << cfg.levels
    for lvl in reversed(range(cfg.levels)):
        c_skip = cfg.base_c << lvl
        block(f"dec{lvl}", c + c_skip, c_skip)
        c = c_skip
    w, b = _conv_init(nk(), cfg.img_channels, c)
    params["head.w"], params["head.b"] = w, b
    return params


def param_order(cfg: UnetCfg):
    """Canonical flat ordering of parameter names (the rust side indexes
    artifact inputs by this order)."""
    names = ["stem.w", "stem.b"]
    c = cfg.base_c
    for lvl in range(cfg.levels):
        c_out = cfg.base_c << lvl
        names += _block_param_names(f"enc{lvl}", c, c_out)
        c = c_out
    names += _block_param_names("mid", c, cfg.base_c << cfg.levels)
    c = cfg.base_c << cfg.levels
    for lvl in reversed(range(cfg.levels)):
        c_skip = cfg.base_c << lvl
        names += _block_param_names(f"dec{lvl}", c + c_skip, c_skip)
        c = c_skip
    names += ["head.w", "head.b"]
    return names


def flatten_params(params, cfg: UnetCfg):
    return [params[n] for n in param_order(cfg)]


def unflatten_params(flat, cfg: UnetCfg):
    return dict(zip(param_order(cfg), flat))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _use_kernel(c_out):
    return c_out % sf_conv.OC_TILE == 0


def _block_apply(params, tag, x, t_emb, c_in, c_out):
    """One U-net block via the two SF kernel modes."""
    w1, b1 = params[f"{tag}.w1"], params[f"{tag}.b1"]
    wt = params[f"{tag}.wt"]
    h = sf_conv.sf_conv3x3_time(x, w1, b1, t_emb, wt)
    h = ref.silu(h)
    w2, b2 = params[f"{tag}.w2"], params[f"{tag}.b2"]
    if c_in == c_out:
        return sf_conv.sf_conv3x3(h, w2, b2, x)
    return sf_conv.sf_conv3x3_resconv(h, w2, b2, x, params[f"{tag}.wres"])


def unet_apply(params, x, t_emb, cfg: UnetCfg):
    """Noise prediction eps_theta(x, t). x: [C,H,W]; t_emb: [time_dim]."""
    # Stem and head have non-tileable channel counts (img_channels=1), so
    # they lower as plain XLA convs — they are series layers, not SF ones.
    h = ref.silu(ref.conv2d(x, params["stem.w"], params["stem.b"]))

    skips = []
    c = cfg.base_c
    for lvl in range(cfg.levels):
        c_out = cfg.base_c << lvl
        h = _block_apply(params, f"enc{lvl}", h, t_emb, c, c_out)
        skips.append(h)
        # pooling unit as a channel-tiled pallas kernel (kernels/pool.py)
        h = pool.maxpool2(h) if c_out % 8 == 0 else ref.maxpool2(h)
        c = c_out

    h = _block_apply(params, "mid", h, t_emb, c, cfg.base_c << cfg.levels)
    c = cfg.base_c << cfg.levels

    for lvl in reversed(range(cfg.levels)):
        h = pool.upsample2(h) if c % 8 == 0 else ref.upsample2(h)
        h = jnp.concatenate([h, skips[lvl]], axis=0)
        c_skip = cfg.base_c << lvl
        h = _block_apply(params, f"dec{lvl}", h, t_emb, c + c_skip, c_skip)
        c = c_skip

    return ref.conv2d(h, params["head.w"], params["head.b"])


def denoise_step(params, x_t, t_emb, c1, c2, sigma, noise, cfg: UnetCfg):
    """One DDPM reverse step with coefficients supplied by the caller
    (the rust coordinator owns the beta schedule):

        x_{t-1} = c1 * (x_t - c2 * eps_theta(x_t, t)) + sigma * noise
    """
    eps = unet_apply(params, x_t, t_emb, cfg)
    return c1 * (x_t - c2 * eps) + sigma * noise


def denoise_scan(params, x_t, t_embs, coeffs, noises, cfg: UnetCfg):
    """The whole reverse process fused into one executable (§Perf, L2):
    `lax.scan` over T steps keeps x device-resident and removes the
    per-step dispatch overhead of the step-at-a-time artifact.

    t_embs: [T, time_dim]; coeffs: [T, 3] (c1, c2, sigma); noises:
    [T, C, H, W] — all precomputed by the rust coordinator, ordered from
    t = T-1 down to t = 0.
    """
    import jax

    def step(x, inp):
        t_emb, coeff, noise = inp
        eps = unet_apply(params, x, t_emb, cfg)
        x2 = coeff[0] * (x - coeff[1] * eps) + coeff[2] * noise
        return x2, ()

    x0, _ = jax.lax.scan(step, x_t, (t_embs, coeffs, noises))
    return x0


# ---------------------------------------------------------------------------
# Reference implementation of the whole net (no pallas) for testing
# ---------------------------------------------------------------------------

def unet_apply_ref(params, x, t_emb, cfg: UnetCfg):
    """Oracle: same network with two-pass ref ops everywhere."""

    def block(tag, x, c_in, c_out):
        h = ref.sf_conv_time(
            x, params[f"{tag}.w1"], params[f"{tag}.b1"], t_emb, params[f"{tag}.wt"]
        )
        h = ref.silu(h)
        if c_in == c_out:
            return ref.sf_conv_residual(h, params[f"{tag}.w2"], params[f"{tag}.b2"], x)
        return ref.sf_conv_residual_conv(
            h, params[f"{tag}.w2"], params[f"{tag}.b2"], x, params[f"{tag}.wres"]
        )

    h = ref.silu(ref.conv2d(x, params["stem.w"], params["stem.b"]))
    skips = []
    c = cfg.base_c
    for lvl in range(cfg.levels):
        c_out = cfg.base_c << lvl
        h = block(f"enc{lvl}", h, c, c_out)
        skips.append(h)
        h = ref.maxpool2(h)
        c = c_out
    h = block("mid", h, c, cfg.base_c << cfg.levels)
    c = cfg.base_c << cfg.levels
    for lvl in reversed(range(cfg.levels)):
        h = ref.upsample2(h)
        h = jnp.concatenate([h, skips[lvl]], axis=0)
        c_skip = cfg.base_c << lvl
        h = block(f"dec{lvl}", h, c + c_skip, c_skip)
        c = c_skip
    return ref.conv2d(h, params["head.w"], params["head.b"])


# ---------------------------------------------------------------------------
# Standalone SF blocks (quickstart / resnet-style artifacts)
# ---------------------------------------------------------------------------

def sf_block(x, w, b, skip):
    """A single fused SF conv+skip block (the quickstart artifact)."""
    return sf_conv.sf_conv3x3(x, w, b, skip)


def resnet_block(x, w1, b1, w2, b2):
    """A ResNet basic block: relu(conv2(relu(conv1(x))) + x), with the
    skip fused into conv2 via the SF kernel."""
    h = ref.relu(sf_conv.sf_conv3x3_plain(x, w1, b1))
    return ref.relu(sf_conv.sf_conv3x3(h, w2, b2, x))
