"""AOT lowering: jax functions -> HLO *text* artifacts + weight blobs.

HLO text (NOT `lowered.compiler_ir("hlo").serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (all lowered with return_tuple=True):

  sf_block_16.hlo.txt       (x[8,16,16], w[8,8,3,3], b[8], skip[8,16,16])
  resnet_block_16.hlo.txt   (x[8,16,16], w1, b1, w2, b2)
  unet_eps_16.hlo.txt       (x[1,16,16], t_emb[32], *params)
  unet_denoise_16.hlo.txt   (x[1,16,16], t_emb[32], c1, c2, sigma,
                             noise[1,16,16], *params)
  unet_params.bin/.manifest weights for the two unet artifacts
  ARTIFACTS.txt             human-readable input inventory

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import UnetCfg


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_fn(fn, arg_specs):
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def write_params(params, order, out_dir, stem="unet_params"):
    """Flat little-endian f32 blob + manifest ('name shape...' per line)."""
    bin_path = os.path.join(out_dir, f"{stem}.bin")
    man_path = os.path.join(out_dir, f"{stem}.manifest")
    with open(bin_path, "wb") as fb, open(man_path, "w") as fm:
        for name in order:
            arr = jnp.asarray(params[name], dtype=jnp.float32)
            fm.write(f"{name} {' '.join(str(d) for d in arr.shape)}\n")
            data = bytes(arr.tobytes())
            assert len(data) == 4 * arr.size
            fb.write(data)
    return bin_path, man_path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--train-steps",
        type=int,
        default=300,
        help="build-time DDPM training steps (0 = ship untrained weights)",
    )
    ap.add_argument("--train-t-max", type=int, default=50)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = UnetCfg()
    inventory = []

    def emit(name, fn, arg_specs, desc):
        text = lower_fn(fn, arg_specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        inventory.append(f"{name}: {desc}")
        print(f"wrote {path} ({len(text)} chars)")

    # --- standalone SF blocks -------------------------------------------
    emit(
        "sf_block_16",
        model.sf_block,
        [spec([8, 16, 16]), spec([8, 8, 3, 3]), spec([8]), spec([8, 16, 16])],
        "x[8,16,16] w[8,8,3,3] b[8] skip[8,16,16] -> conv+skip",
    )
    emit(
        "resnet_block_16",
        model.resnet_block,
        [
            spec([8, 16, 16]),
            spec([8, 8, 3, 3]),
            spec([8]),
            spec([8, 8, 3, 3]),
            spec([8]),
        ],
        "x w1 b1 w2 b2 -> relu(conv2(relu(conv1 x)) + x)",
    )

    # --- U-net ------------------------------------------------------------
    if args.train_steps > 0:
        from . import train

        params, losses = train.train_unet(
            cfg, t_max=args.train_t_max, steps=args.train_steps, seed=args.seed
        )
        loss_path = os.path.join(args.out_dir, "train_loss.txt")
        with open(loss_path, "w") as f:
            f.write("# step loss (DDPM eps-prediction MSE)\n")
            for i, l in enumerate(losses):
                f.write(f"{i} {l:.6f}\n")
        print(
            f"trained {args.train_steps} steps: loss {losses[0]:.4f} -> "
            f"{losses[-1]:.4f}; curve at {loss_path}"
        )
    else:
        params = model.init_params(cfg, seed=args.seed)
    order = model.param_order(cfg)
    pspecs = [spec(params[n].shape) for n in order]

    def eps_fn(x, t_emb, *flat):
        p = model.unflatten_params(list(flat), cfg)
        return model.unet_apply(p, x, t_emb, cfg)

    emit(
        "unet_eps_16",
        eps_fn,
        [spec([cfg.img_channels, cfg.img, cfg.img]), spec([cfg.time_dim])] + pspecs,
        f"x[{cfg.img_channels},{cfg.img},{cfg.img}] t_emb[{cfg.time_dim}] "
        f"*{len(order)} params -> eps",
    )

    def denoise_fn(x, t_emb, c1, c2, sigma, noise, *flat):
        p = model.unflatten_params(list(flat), cfg)
        return model.denoise_step(p, x, t_emb, c1, c2, sigma, noise, cfg)

    emit(
        "unet_denoise_16",
        denoise_fn,
        [
            spec([cfg.img_channels, cfg.img, cfg.img]),
            spec([cfg.time_dim]),
            spec([]),
            spec([]),
            spec([]),
            spec([cfg.img_channels, cfg.img, cfg.img]),
        ]
        + pspecs,
        "x t_emb c1 c2 sigma noise *params -> x_{t-1}",
    )

    # §Perf (L2): the whole T-step reverse process as ONE executable —
    # lax.scan keeps the image device-resident across steps.
    t_steps = args.train_t_max

    def scan_fn(x, t_embs, coeffs, noises, *flat):
        p = model.unflatten_params(list(flat), cfg)
        return model.denoise_scan(p, x, t_embs, coeffs, noises, cfg)

    emit(
        f"unet_denoise_scan{t_steps}_16",
        scan_fn,
        [
            spec([cfg.img_channels, cfg.img, cfg.img]),
            spec([t_steps, cfg.time_dim]),
            spec([t_steps, 3]),
            spec([t_steps, cfg.img_channels, cfg.img, cfg.img]),
        ]
        + pspecs,
        f"x t_embs[{t_steps},{cfg.time_dim}] coeffs[{t_steps},3] "
        f"noises[{t_steps},...] *params -> x_0 (fused {t_steps}-step scan)",
    )

    bin_path, man_path = write_params(params, order, args.out_dir)
    print(f"wrote {bin_path}, {man_path}")

    with open(os.path.join(args.out_dir, "ARTIFACTS.txt"), "w") as f:
        f.write("\n".join(inventory) + "\n")
        f.write(f"unet params: {len(order)} tensors, order as in manifest\n")

    # Struct sanity: manifest element counts must cover the blob exactly.
    total = 0
    with open(man_path) as f:
        for line in f:
            parts = line.split()
            dims = [int(d) for d in parts[1:]]
            n = 1
            for d in dims:
                n *= d
            total += n
    blob = os.path.getsize(bin_path)
    assert blob == 4 * total, f"blob {blob} != 4*{total}"
    print(f"params blob OK: {total} f32 values")
    # struct import kept for readers extending this with other dtypes
    _ = struct


if __name__ == "__main__":
    main()
