"""Build-time DDPM training of the small U-net on synthetic data.

The serving demo needs a *meaningful* de-noiser: an untrained eps-net
feeds back through the reverse process and diverges. We train for a few
hundred Adam steps on a synthetic 2-D Gaussian-blob dataset (the kind of
tiny corpus the de-noise figures in diffusion papers start from), log the
loss curve, and bake the trained weights into `unet_params.bin`.

Training differentiates through `unet_apply_ref` (pure jnp — pallas
interpret kernels do not define a VJP); the pallas net is numerically
identical to it (pytest: test_kernel_net_matches_ref_net), so the weights
transfer exactly.
"""

import jax
import jax.numpy as jnp

from . import model
from .model import UnetCfg


def synth_batch(key, n, img):
    """Synthetic images: 1-3 Gaussian blobs on a [-1, 1] canvas."""
    keys = jax.random.split(key, 4)
    yy, xx = jnp.mgrid[0:img, 0:img]
    centers = jax.random.uniform(keys[0], (n, 3, 2), minval=2.0, maxval=img - 2.0)
    widths = jax.random.uniform(keys[1], (n, 3), minval=1.0, maxval=3.0)
    amps = jax.random.uniform(keys[2], (n, 3), minval=0.5, maxval=1.0)
    alive = jax.random.bernoulli(keys[3], 0.7, (n, 3)).astype(jnp.float32)
    d2 = (
        (yy[None, None] - centers[..., 0, None, None]) ** 2
        + (xx[None, None] - centers[..., 1, None, None]) ** 2
    )
    blobs = (amps * alive)[..., None, None] * jnp.exp(
        -d2 / (2.0 * widths[..., None, None] ** 2)
    )
    imgs = blobs.sum(axis=1)
    return (imgs * 2.0 - 1.0).clip(-1.0, 1.0)[:, None]  # [n,1,H,W]


def ddpm_schedule(t_max, beta_lo=1e-4, beta_hi=0.02):
    """Must match rust `DdpmSchedule::linear` exactly."""
    if t_max == 1:
        betas = jnp.array([beta_lo])
    else:
        betas = beta_lo + (beta_hi - beta_lo) * jnp.arange(t_max) / (t_max - 1)
    alphas = 1.0 - betas
    alpha_bars = jnp.cumprod(alphas)
    return betas, alphas, alpha_bars


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


def adam_step(params, grads, state, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    mhat = {k: m[k] / (1 - b1**t) for k in params}
    vhat = {k: v[k] / (1 - b2**t) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


def train_unet(cfg: UnetCfg, t_max=50, steps=300, batch=8, seed=0, lr=2e-3):
    """Train; returns (params, loss_history)."""
    params = model.init_params(cfg, seed=seed)
    _, _, alpha_bars = ddpm_schedule(t_max)

    def loss_fn(p, x0, t, noise):
        ab = alpha_bars[t]
        x_t = jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * noise
        t_emb = model.time_embedding(t.astype(jnp.float32), cfg.time_dim)
        eps_hat = model.unet_apply_ref(p, x_t, t_emb, cfg)
        return jnp.mean((eps_hat - noise) ** 2)

    def batch_loss(p, x0s, ts, noises):
        losses = jax.vmap(lambda x0, t, n: loss_fn(p, x0, t, n))(x0s, ts, noises)
        return losses.mean()

    @jax.jit
    def train_step(p, opt, key):
        k1, k2, k3 = jax.random.split(key, 3)
        x0s = synth_batch(k1, batch, cfg.img)
        ts = jax.random.randint(k2, (batch,), 0, t_max)
        noises = jax.random.normal(k3, (batch, cfg.img_channels, cfg.img, cfg.img))
        l, grads = jax.value_and_grad(batch_loss)(p, x0s, ts, noises)
        p2, opt2 = adam_step(p, grads, opt, lr=lr)
        return p2, opt2, l

    opt = adam_init(params)
    key = jax.random.PRNGKey(seed + 1234)
    losses = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, opt, l = train_step(params, opt, sub)
        losses.append(float(l))
        if i % 50 == 0 or i == steps - 1:
            print(f"train step {i:4d}  loss {float(l):.4f}")
    return params, losses
