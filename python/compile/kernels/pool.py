"""Pallas kernels for the peripheral units of Fig 18: the pooling unit
and the decoder's upsampler.

These are not server-flow layers (no PE_9 branch), but lowering them as
Pallas kernels keeps the *whole* U-net inside the same VMEM-tiled
schedule — one grid step per 8-channel tile, matching `sf_conv.py`.
Validated against `ref.maxpool2` / `ref.upsample2` in
python/tests/test_pool_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sf_conv import OC_TILE


def _maxpool2_kernel(x_ref, o_ref):
    x = x_ref[...]
    c, h, w = x.shape
    o_ref[...] = x.reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))


@functools.partial(jax.jit, static_argnames=())
def maxpool2(x):
    """2x2/2 max pool, CHW, channel-tiled. Channels must tile by 8."""
    c, h, w = x.shape
    assert c % OC_TILE == 0, f"channels {c} must tile by {OC_TILE}"
    assert h % 2 == 0 and w % 2 == 0, "even spatial dims required"
    return pl.pallas_call(
        _maxpool2_kernel,
        grid=(c // OC_TILE,),
        in_specs=[pl.BlockSpec((OC_TILE, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((OC_TILE, h // 2, w // 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h // 2, w // 2), jnp.float32),
        interpret=True,
    )(x)


def _upsample2_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)


@functools.partial(jax.jit, static_argnames=())
def upsample2(x):
    """Nearest-neighbour 2x upsample, CHW, channel-tiled."""
    c, h, w = x.shape
    assert c % OC_TILE == 0, f"channels {c} must tile by {OC_TILE}"
    return pl.pallas_call(
        _upsample2_kernel,
        grid=(c // OC_TILE,),
        in_specs=[pl.BlockSpec((OC_TILE, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((OC_TILE, h * 2, w * 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h * 2, w * 2), jnp.float32),
        interpret=True,
    )(x)


def _gap_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = x.mean(axis=(1, 2))


@functools.partial(jax.jit, static_argnames=())
def global_avg_pool(x):
    """Global average pool to [C] (ResNet head), channel-tiled."""
    c, h, w = x.shape
    assert c % OC_TILE == 0, f"channels {c} must tile by {OC_TILE}"
    return pl.pallas_call(
        _gap_kernel,
        grid=(c // OC_TILE,),
        in_specs=[pl.BlockSpec((OC_TILE, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((OC_TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=True,
    )(x)
