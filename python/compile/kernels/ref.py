"""Pure-jnp oracles for the Pallas kernels.

These are the *unfused two-pass* references: main conv as one pass, the
parallel branch (identity skip / 1x1 residual conv / time bias) as a
second pass. The SF kernel must match them bit-for-close while doing the
work in a single fused pass — that is exactly the paper's claim, restated
numerically.

All tensors are CHW (batch size is 1 throughout, per the paper §III.D).
"""

import jax.numpy as jnp
from jax import lax


def conv2d(x, w, b=None, stride=1, pad=1):
    """Plain 2-D convolution. x: [C,H,W]; w: [O,C,k,k]; b: [O]."""
    out = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if b is not None:
        out = out + b[:, None, None]
    return out


def sf_conv_residual(x, w, b, skip):
    """Conv + identity skip (SF ResidualIdentity mode, Fig 6b)."""
    return conv2d(x, w, b) + skip


def sf_conv_residual_conv(x, w, b, skip, w_res):
    """Conv + 1x1-conv skip (SF ResidualConv mode, Fig 6c).

    skip: [Cs,H,W]; w_res: [O,Cs] — the 1x1 conv PE_9 computes.
    """
    res = jnp.einsum("oc,chw->ohw", w_res, skip)
    return conv2d(x, w, b) + res


def sf_conv_time(x, w, b, t_emb, w_time):
    """Conv + time-parameter dense bias (SF DenseTime mode, Figs 14-16).

    t_emb: [T]; w_time: [O,T]; the dense output biases each channel.
    """
    tb = w_time @ t_emb
    return conv2d(x, w, b) + tb[:, None, None]


def dense(x, w, b):
    """Dense layer. x: [I]; w: [O,I]; b: [O]."""
    return w @ x + b


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool2(x):
    """2x2/2 max pool, CHW."""
    c, h, w = x.shape
    return x.reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))


def upsample2(x):
    """Nearest-neighbour 2x upsample, CHW."""
    return jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)
