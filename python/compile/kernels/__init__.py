"""L1 Pallas kernels: the SF-MMCN compute hot-spot.

`sf_conv` implements the server-flow fused conv+branch dataflow; `ref`
holds the pure-jnp oracles the kernels are validated against (pytest +
hypothesis in python/tests/).
"""

from . import pool, ref, sf_conv  # noqa: F401
