"""The SF-MMCN Pallas kernel: server-flow fused convolution.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's insight
is that the parallel branch of a residual/U-net block costs *zero extra
passes* because PE_9 serves it inside the main convolution's schedule. On
a TPU-shaped machine the analogue is **fusion inside one VMEM-resident
grid step**: each grid step brings one 8-output-channel tile of weights
(the "8 worker PEs") plus the input tile and the branch tile into VMEM,
and computes

    out_tile = conv3x3(x, w_tile) + branch_tile          (identity skip)
    out_tile = conv3x3(x, w_tile) + w_res_tile @ skip    (1x1 residual conv)
    out_tile = conv3x3(x, w_tile) + w_time_tile @ t_emb  (time dense)

in a single pass — one HBM->VMEM round-trip instead of two kernels.
The 3x3 conv itself is expressed as 9 shifted (8xC)@(CxHW) matmuls, which
is the MXU-systolic-array shape (the analogue of the paper's "8 PEs
deliver 8 outputs at once"); the Q8.8 datapath of the silicon maps to
bf16/f32 MXU accumulation here.

Kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (vs `ref.py`) is what is being
reproduced. The BlockSpec structure is still the TPU schedule; DESIGN.md
§Perf estimates its VMEM footprint and MXU utilization.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-channel tile: one "SF-MMCN unit group" of 8 worker lanes.
OC_TILE = 8


def _conv3x3_tile(x_pad, w_tile, h, wd):
    """3x3 conv of a padded CHW input against an [8,C,3,3] weight tile,
    as 9 MXU matmuls: for each tap (ky,kx), (8xC) @ (CxH*W)."""
    acc = jnp.zeros((OC_TILE, h * wd), dtype=jnp.float32)
    for ky in range(3):
        for kx in range(3):
            patch = jax.lax.dynamic_slice(
                x_pad, (0, ky, kx), (x_pad.shape[0], h, wd)
            ).reshape(x_pad.shape[0], h * wd)
            acc = acc + jnp.dot(
                w_tile[:, :, ky, kx], patch, preferred_element_type=jnp.float32
            )
    return acc.reshape(OC_TILE, h, wd)


def _sf_kernel_identity(x_ref, w_ref, b_ref, skip_ref, o_ref):
    """Fused conv3x3 + identity skip (SF ResidualIdentity)."""
    x = x_ref[...]
    c, hp, wp = x.shape
    h, wd = hp - 2, wp - 2
    out = _conv3x3_tile(x, w_ref[...], h, wd)
    o_ref[...] = out + b_ref[...][:, None, None] + skip_ref[...]


def _sf_kernel_resconv(x_ref, w_ref, b_ref, skip_ref, wres_ref, o_ref):
    """Fused conv3x3 + 1x1-conv skip (SF ResidualConv): PE_9's matmul."""
    x = x_ref[...]
    c, hp, wp = x.shape
    h, wd = hp - 2, wp - 2
    out = _conv3x3_tile(x, w_ref[...], h, wd)
    skip = skip_ref[...]
    res = jnp.dot(
        wres_ref[...],
        skip.reshape(skip.shape[0], h * wd),
        preferred_element_type=jnp.float32,
    ).reshape(OC_TILE, h, wd)
    o_ref[...] = out + b_ref[...][:, None, None] + res


def _sf_kernel_time(x_ref, w_ref, b_ref, temb_ref, wtime_ref, o_ref):
    """Fused conv3x3 + time-parameter dense bias (SF DenseTime)."""
    x = x_ref[...]
    c, hp, wp = x.shape
    h, wd = hp - 2, wp - 2
    out = _conv3x3_tile(x, w_ref[...], h, wd)
    tb = jnp.dot(wtime_ref[...], temb_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = out + (b_ref[...] + tb)[:, None, None]


def _pad_hw(x):
    return jnp.pad(x, ((0, 0), (1, 1), (1, 1)))


def _check(x, w, b):
    c, h, wd = x.shape
    o = w.shape[0]
    assert w.shape == (o, c, 3, 3), f"weights {w.shape} not [O,{c},3,3]"
    assert b.shape == (o,), f"bias {b.shape}"
    assert o % OC_TILE == 0, f"output channels {o} must tile by {OC_TILE}"
    return c, h, wd, o


@functools.partial(jax.jit, static_argnames=())
def sf_conv3x3(x, w, b, skip):
    """conv3x3(x, w) + b + skip, fused. x: [C,H,W]; w: [O,C,3,3];
    skip: [O,H,W]. Grid over output-channel tiles of 8."""
    c, h, wd, o = _check(x, w, b)
    assert skip.shape == (o, h, wd), f"skip {skip.shape}"
    x_pad = _pad_hw(x)
    grid = (o // OC_TILE,)
    return pl.pallas_call(
        _sf_kernel_identity,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, h + 2, wd + 2), lambda i: (0, 0, 0)),
            pl.BlockSpec((OC_TILE, c, 3, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((OC_TILE,), lambda i: (i,)),
            pl.BlockSpec((OC_TILE, h, wd), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((OC_TILE, h, wd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((o, h, wd), jnp.float32),
        interpret=True,
    )(x_pad, w, b, skip)


@functools.partial(jax.jit, static_argnames=())
def sf_conv3x3_resconv(x, w, b, skip, w_res):
    """conv3x3(x, w) + b + (w_res @ skip), fused. skip: [Cs,H,W];
    w_res: [O,Cs] — PE_9's 1x1 residual conv."""
    c, h, wd, o = _check(x, w, b)
    cs = skip.shape[0]
    assert skip.shape == (cs, h, wd)
    assert w_res.shape == (o, cs)
    x_pad = _pad_hw(x)
    grid = (o // OC_TILE,)
    return pl.pallas_call(
        _sf_kernel_resconv,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, h + 2, wd + 2), lambda i: (0, 0, 0)),
            pl.BlockSpec((OC_TILE, c, 3, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((OC_TILE,), lambda i: (i,)),
            pl.BlockSpec((cs, h, wd), lambda i: (0, 0, 0)),
            pl.BlockSpec((OC_TILE, cs), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((OC_TILE, h, wd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((o, h, wd), jnp.float32),
        interpret=True,
    )(x_pad, w, b, skip, w_res)


@functools.partial(jax.jit, static_argnames=())
def sf_conv3x3_time(x, w, b, t_emb, w_time):
    """conv3x3(x, w) + b + (w_time @ t_emb) per-channel bias, fused.
    t_emb: [T]; w_time: [O,T] — PE_9's time-parameter dense."""
    c, h, wd, o = _check(x, w, b)
    t = t_emb.shape[0]
    assert w_time.shape == (o, t)
    x_pad = _pad_hw(x)
    grid = (o // OC_TILE,)
    return pl.pallas_call(
        _sf_kernel_time,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, h + 2, wd + 2), lambda i: (0, 0, 0)),
            pl.BlockSpec((OC_TILE, c, 3, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((OC_TILE,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((OC_TILE, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((OC_TILE, h, wd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((o, h, wd), jnp.float32),
        interpret=True,
    )(x_pad, w, b, t_emb, w_time)


@functools.partial(jax.jit, static_argnames=())
def sf_conv3x3_plain(x, w, b):
    """Series-mode conv (PE_9 idle): conv3x3 + b, same tiling."""
    c, h, wd, o = _check(x, w, b)
    zero_skip = jnp.zeros((o, h, wd), dtype=jnp.float32)
    return sf_conv3x3(x, w, b, zero_skip)


def vmem_footprint_bytes(c, h, w, cs=0, t=0, dtype_bytes=4):
    """Static VMEM estimate for one grid step (DESIGN.md §Perf):
    input tile + weight tile + branch tile + output tile."""
    x_tile = c * (h + 2) * (w + 2)
    w_tile = OC_TILE * c * 9
    branch = max(cs, OC_TILE) * h * w if cs else OC_TILE * h * w
    time = OC_TILE * t + t
    out = OC_TILE * h * w
    return (x_tile + w_tile + branch + time + out) * dtype_bytes


def mxu_utilization_estimate(c, h, w):
    """Fraction of MXU 128x128 lanes engaged by the (8xC)@(Cx(H*W))
    matmuls — the structural efficiency measure we report in lieu of
    silicon timings (interpret=True timing is meaningless)."""
    m, k, n = OC_TILE, c, h * w
    eff_m = min(m, 128) / 128.0
    eff_k = min(k, 128) / 128.0
    eff_n = min(n, 128) / 128.0
    return eff_m * eff_k * eff_n
