"""L2 correctness: the U-net (pallas kernels) vs its all-ref oracle, the
DDPM step algebra, and the parameter flattening contract the rust runtime
depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.model import UnetCfg

CFG = UnetCfg()


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


class TestUnet:
    def test_output_shape(self, params):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 16))
        t_emb = model.time_embedding(3.0, CFG.time_dim)
        eps = model.unet_apply(params, x, t_emb, CFG)
        assert eps.shape == (1, 16, 16)

    def test_kernel_net_matches_ref_net(self, params):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
        t_emb = model.time_embedding(10.0, CFG.time_dim)
        got = model.unet_apply(params, x, t_emb, CFG)
        want = model.unet_apply_ref(params, x, t_emb, CFG)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_time_conditioning_changes_output(self, params):
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16))
        e1 = model.unet_apply(params, x, model.time_embedding(1.0, CFG.time_dim), CFG)
        e2 = model.unet_apply(params, x, model.time_embedding(100.0, CFG.time_dim), CFG)
        assert float(jnp.abs(e1 - e2).max()) > 1e-3

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_ref_agreement_random_inputs(self, params, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, 16))
        t_emb = model.time_embedding(float(seed % 50), CFG.time_dim)
        got = model.unet_apply(params, x, t_emb, CFG)
        want = model.unet_apply_ref(params, x, t_emb, CFG)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_other_configs_build(self):
        for cfg in [
            UnetCfg(img=8, base_c=8, levels=1),
            UnetCfg(img=32, base_c=8, levels=2),
        ]:
            p = model.init_params(cfg, seed=1)
            x = jnp.zeros((1, cfg.img, cfg.img))
            t = model.time_embedding(0.0, cfg.time_dim)
            out = model.unet_apply(p, x, t, cfg)
            assert out.shape == (1, cfg.img, cfg.img)


class TestDenoiseStep:
    def test_algebra(self, params):
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16))
        t_emb = model.time_embedding(5.0, CFG.time_dim)
        noise = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 16))
        c1, c2, sigma = 1.01, 0.05, 0.1
        got = model.denoise_step(params, x, t_emb, c1, c2, sigma, noise, CFG)
        eps = model.unet_apply(params, x, t_emb, CFG)
        want = c1 * (x - c2 * eps) + sigma * noise
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_scan_matches_unrolled_steps(self, params):
        """The fused lax.scan artifact must equal the step-at-a-time loop."""
        T = 4
        x = jax.random.normal(jax.random.PRNGKey(9), (1, 16, 16))
        t_embs = jnp.stack(
            [model.time_embedding(float(t), CFG.time_dim) for t in reversed(range(T))]
        )
        coeffs = jnp.array([[1.01, 0.05, 0.1 if t > 0 else 0.0] for t in reversed(range(T))])
        noises = jax.random.normal(jax.random.PRNGKey(10), (T, 1, 16, 16))
        fused = model.denoise_scan(params, x, t_embs, coeffs, noises, CFG)
        xs = x
        for i in range(T):
            xs = model.denoise_step(
                params, xs, t_embs[i], coeffs[i, 0], coeffs[i, 1], coeffs[i, 2],
                noises[i], CFG,
            )
        np.testing.assert_allclose(fused, xs, rtol=1e-4, atol=1e-5)

    def test_zero_sigma_is_deterministic(self, params):
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 16))
        t_emb = model.time_embedding(5.0, CFG.time_dim)
        n1 = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 16))
        n2 = jax.random.normal(jax.random.PRNGKey(7), (1, 16, 16))
        a = model.denoise_step(params, x, t_emb, 1.0, 0.1, 0.0, n1, CFG)
        b = model.denoise_step(params, x, t_emb, 1.0, 0.1, 0.0, n2, CFG)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


class TestTimeEmbedding:
    def test_shape_and_range(self):
        e = model.time_embedding(7.0, 32)
        assert e.shape == (32,)
        assert float(jnp.abs(e).max()) <= 1.0 + 1e-6

    def test_distinct_timesteps_distinct_embeddings(self):
        e1 = model.time_embedding(1.0, 32)
        e2 = model.time_embedding(2.0, 32)
        assert float(jnp.abs(e1 - e2).max()) > 1e-3


class TestParamContract:
    """The rust runtime streams params by manifest order — pin it."""

    def test_order_matches_params(self, params):
        order = model.param_order(CFG)
        assert sorted(order) == sorted(params.keys())

    def test_flatten_roundtrip(self, params):
        flat = model.flatten_params(params, CFG)
        back = model.unflatten_params(flat, CFG)
        assert set(back.keys()) == set(params.keys())
        for k in params:
            np.testing.assert_array_equal(back[k], params[k])

    def test_order_is_stable(self):
        assert model.param_order(CFG) == model.param_order(CFG)
        # first and last are stem/head — the rust loader relies on this
        order = model.param_order(CFG)
        assert order[0] == "stem.w"
        assert order[-1] == "head.b"

    def test_blocks_with_channel_change_have_wres(self, params):
        # decoder blocks concat -> c_in != c_out -> need wres
        assert "dec0.wres" in params
        assert "dec1.wres" in params
        # enc0 keeps base_c -> identity skip, no wres
        assert "enc0.wres" not in params

    def test_init_deterministic(self):
        p1 = model.init_params(CFG, seed=0)
        p2 = model.init_params(CFG, seed=0)
        for k in p1:
            np.testing.assert_array_equal(p1[k], p2[k])

    def test_different_seeds_differ(self):
        p1 = model.init_params(CFG, seed=0)
        p2 = model.init_params(CFG, seed=1)
        assert float(jnp.abs(p1["stem.w"] - p2["stem.w"]).max()) > 1e-4


class TestStandaloneBlocks:
    def test_resnet_block_numerics(self):
        x = jax.random.normal(jax.random.PRNGKey(8), (8, 16, 16))
        w1 = jax.random.normal(jax.random.PRNGKey(9), (8, 8, 3, 3)) * 0.1
        b1 = jnp.zeros(8)
        w2 = jax.random.normal(jax.random.PRNGKey(10), (8, 8, 3, 3)) * 0.1
        b2 = jnp.zeros(8)
        got = model.resnet_block(x, w1, b1, w2, b2)
        from compile.kernels import ref

        h = ref.relu(ref.conv2d(x, w1, b1))
        want = ref.relu(ref.conv2d(h, w2, b2) + x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_sf_block_is_identity_mode_kernel(self):
        x = jnp.ones((8, 16, 16)) * 0.5
        w = jnp.ones((8, 8, 3, 3)) * 0.1
        b = jnp.zeros(8)
        skip = jnp.ones((8, 16, 16))
        out = model.sf_block(x, w, b, skip)
        # interior: 9 taps * 8 ch * 0.05 + 1.0 = 4.6 (this exact value is
        # asserted again from rust in rust/tests/runtime_smoke.rs)
        assert abs(float(out[0, 8, 8]) - 4.6) < 1e-4
