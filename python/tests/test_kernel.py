"""L1 correctness: Pallas SF kernels vs the pure-jnp oracle.

This is the core correctness signal of the compile path: the fused
single-pass kernel must match the unfused two-pass reference. Hypothesis
sweeps shapes; fixed cases pin the exact modes the paper draws in Fig 6.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sf_conv

TOL = dict(rtol=1e-4, atol=1e-4)


def rnd(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


class TestIdentityMode:
    """SF ResidualIdentity (Fig 6b): conv + served skip."""

    def test_basic(self):
        x = rnd(0, (4, 8, 8))
        w = rnd(1, (8, 4, 3, 3), 0.2)
        b = jnp.arange(8.0) * 0.1
        skip = rnd(2, (8, 8, 8))
        got = sf_conv.sf_conv3x3(x, w, b, skip)
        want = ref.sf_conv_residual(x, w, b, skip)
        np.testing.assert_allclose(got, want, **TOL)

    def test_zero_skip_equals_plain_conv(self):
        x = rnd(3, (4, 8, 8))
        w = rnd(4, (8, 4, 3, 3), 0.2)
        b = rnd(5, (8,), 0.1)
        got = sf_conv.sf_conv3x3_plain(x, w, b)
        want = ref.conv2d(x, w, b)
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=25, deadline=None)
    @given(
        c=st.integers(1, 12),
        hw=st.integers(3, 14),
        octiles=st.integers(1, 3),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, c, hw, octiles, seed):
        o = octiles * sf_conv.OC_TILE
        x = rnd(seed, (c, hw, hw))
        w = rnd(seed + 1, (o, c, 3, 3), 0.2)
        b = rnd(seed + 2, (o,), 0.1)
        skip = rnd(seed + 3, (o, hw, hw))
        got = sf_conv.sf_conv3x3(x, w, b, skip)
        want = ref.sf_conv_residual(x, w, b, skip)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_rejects_untiled_channels(self):
        x = rnd(0, (4, 8, 8))
        w = rnd(1, (7, 4, 3, 3))
        b = jnp.zeros(7)
        skip = rnd(2, (7, 8, 8))
        with pytest.raises(AssertionError):
            sf_conv.sf_conv3x3(x, w, b, skip)


class TestResidualConvMode:
    """SF ResidualConv (Fig 6c): PE_9's 1x1 conv on the skip branch."""

    def test_basic(self):
        x = rnd(0, (4, 8, 8))
        w = rnd(1, (8, 4, 3, 3), 0.2)
        b = rnd(2, (8,), 0.1)
        skip = rnd(3, (6, 8, 8))
        w_res = rnd(4, (8, 6), 0.3)
        got = sf_conv.sf_conv3x3_resconv(x, w, b, skip, w_res)
        want = ref.sf_conv_residual_conv(x, w, b, skip, w_res)
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=20, deadline=None)
    @given(
        c=st.integers(1, 10),
        cs=st.integers(1, 10),
        hw=st.integers(3, 12),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, c, cs, hw, seed):
        o = sf_conv.OC_TILE
        x = rnd(seed, (c, hw, hw))
        w = rnd(seed + 1, (o, c, 3, 3), 0.2)
        b = rnd(seed + 2, (o,), 0.1)
        skip = rnd(seed + 3, (cs, hw, hw))
        w_res = rnd(seed + 4, (o, cs), 0.3)
        got = sf_conv.sf_conv3x3_resconv(x, w, b, skip, w_res)
        want = ref.sf_conv_residual_conv(x, w, b, skip, w_res)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_1x1_matches_einsum(self):
        # the branch alone: zero main weights isolate PE_9's contribution
        x = jnp.zeros((4, 6, 6))
        w = jnp.zeros((8, 4, 3, 3))
        b = jnp.zeros(8)
        skip = rnd(7, (5, 6, 6))
        w_res = rnd(8, (8, 5))
        got = sf_conv.sf_conv3x3_resconv(x, w, b, skip, w_res)
        want = jnp.einsum("oc,chw->ohw", w_res, skip)
        np.testing.assert_allclose(got, want, **TOL)


class TestTimeDenseMode:
    """SF DenseTime (Figs 14-16): PE_9's time-parameter dense."""

    def test_basic(self):
        x = rnd(0, (4, 8, 8))
        w = rnd(1, (8, 4, 3, 3), 0.2)
        b = rnd(2, (8,), 0.1)
        t_emb = rnd(3, (16,))
        w_time = rnd(4, (8, 16), 0.2)
        got = sf_conv.sf_conv3x3_time(x, w, b, t_emb, w_time)
        want = ref.sf_conv_time(x, w, b, t_emb, w_time)
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=20, deadline=None)
    @given(
        c=st.integers(1, 8),
        t=st.integers(1, 48),
        hw=st.integers(3, 12),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, c, t, hw, seed):
        o = sf_conv.OC_TILE
        x = rnd(seed, (c, hw, hw))
        w = rnd(seed + 1, (o, c, 3, 3), 0.2)
        b = rnd(seed + 2, (o,), 0.1)
        t_emb = rnd(seed + 3, (t,))
        w_time = rnd(seed + 4, (o, t), 0.2)
        got = sf_conv.sf_conv3x3_time(x, w, b, t_emb, w_time)
        want = ref.sf_conv_time(x, w, b, t_emb, w_time)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_time_bias_is_per_channel_constant(self):
        x = rnd(0, (2, 6, 6))
        w = rnd(1, (8, 2, 3, 3), 0.2)
        b = jnp.zeros(8)
        t_emb = rnd(2, (4,))
        w_time = rnd(3, (8, 4))
        with_t = sf_conv.sf_conv3x3_time(x, w, b, t_emb, w_time)
        without = sf_conv.sf_conv3x3_plain(x, w, b)
        diff = with_t - without
        # spatially constant per channel
        per_ch = diff.reshape(8, -1)
        np.testing.assert_allclose(
            per_ch, per_ch[:, :1] * jnp.ones_like(per_ch), rtol=1e-4, atol=1e-5
        )


class TestStructuralEstimates:
    def test_vmem_footprint_monotone_in_channels(self):
        a = sf_conv.vmem_footprint_bytes(8, 16, 16)
        b = sf_conv.vmem_footprint_bytes(64, 16, 16)
        assert b > a

    def test_vmem_fits_16mb_for_paper_shapes(self):
        # U-net 16x16 tiles must fit a TPU core's ~16 MiB VMEM easily
        assert sf_conv.vmem_footprint_bytes(64, 16, 16) < 16 * 2**20

    def test_mxu_estimate_bounds(self):
        for c, h, w in [(1, 4, 4), (64, 16, 16), (128, 32, 32), (256, 64, 64)]:
            u = sf_conv.mxu_utilization_estimate(c, h, w)
            assert 0.0 < u <= 1.0

    def test_mxu_improves_with_spatial_size(self):
        assert sf_conv.mxu_utilization_estimate(64, 16, 16) > sf_conv.mxu_utilization_estimate(
            64, 4, 4
        )
