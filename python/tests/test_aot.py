"""Compile-path tests: HLO-text lowering and the params blob format."""

import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.model import UnetCfg


def test_sf_block_lowers_to_hlo_text():
    specs = [
        aot.spec([8, 16, 16]),
        aot.spec([8, 8, 3, 3]),
        aot.spec([8]),
        aot.spec([8, 16, 16]),
    ]
    text = aot.lower_fn(model.sf_block, specs)
    assert "ENTRY" in text
    assert "f32[8,16,16]" in text
    # return_tuple=True -> tuple-shaped entry result in the module header
    assert "->(f32[8,16,16]{2,1,0})" in text.splitlines()[0]


def test_hlo_text_has_no_serialized_proto_markers():
    # guard: we must ship text, not binary
    specs = [aot.spec([8, 16, 16]), aot.spec([8, 8, 3, 3]), aot.spec([8]),
             aot.spec([8, 16, 16])]
    text = aot.lower_fn(model.sf_block, specs)
    assert text.isprintable() or "\n" in text


def test_params_blob_roundtrip():
    cfg = UnetCfg(img=8, base_c=8, levels=1)
    params = model.init_params(cfg, seed=3)
    order = model.param_order(cfg)
    with tempfile.TemporaryDirectory() as d:
        bin_path, man_path = aot.write_params(params, order, d, stem="p")
        # manifest lines match order
        with open(man_path) as f:
            lines = [l.split() for l in f.read().splitlines()]
        assert [l[0] for l in lines] == order
        # blob parses back to the same values
        blob = open(bin_path, "rb").read()
        off = 0
        for parts in lines:
            name = parts[0]
            dims = [int(x) for x in parts[1:]]
            n = int(np.prod(dims)) if dims else 1
            vals = struct.unpack_from(f"<{n}f", blob, off)
            off += 4 * n
            np.testing.assert_allclose(
                np.array(vals).reshape(dims),
                np.asarray(params[name]),
                rtol=1e-6,
                atol=1e-7,
            )
        assert off == len(blob)


def test_denoise_artifact_arity_matches_manifest():
    """The rust loader passes [x, t_emb, c1, c2, sigma, noise] + params in
    manifest order — pin the total input arity of the lowered module."""
    cfg = UnetCfg()
    order = model.param_order(cfg)
    # 2 stem + 5 blocks x 5 + 4 wres (enc1/mid/dec1/dec0) + 2 head = 33
    assert len(order) == 33
    n_inputs = 6 + len(order)
    params = model.init_params(cfg, seed=0)
    pspecs = [aot.spec(params[n].shape) for n in order]

    def denoise_fn(x, t_emb, c1, c2, sigma, noise, *flat):
        p = model.unflatten_params(list(flat), cfg)
        return model.denoise_step(p, x, t_emb, c1, c2, sigma, noise, cfg)

    lowered = jax.jit(denoise_fn).lower(
        aot.spec([1, 16, 16]), aot.spec([32]), aot.spec([]), aot.spec([]),
        aot.spec([]), aot.spec([1, 16, 16]), *pspecs
    )
    text = aot.to_hlo_text(lowered)
    # count parameters of the ENTRY computation only (nested pallas
    # computations declare their own)
    entry = text.split("ENTRY", 1)[1].split("\n}", 1)[0]
    assert entry.count("parameter(") == n_inputs
