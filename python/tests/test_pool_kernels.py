"""Peripheral-unit Pallas kernels vs the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import pool, ref


def rnd(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


class TestMaxPool:
    def test_basic(self):
        x = rnd(0, (8, 8, 8))
        np.testing.assert_allclose(pool.maxpool2(x), ref.maxpool2(x), rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        ct=st.integers(1, 4),
        hw=st.sampled_from([2, 4, 6, 10, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, ct, hw, seed):
        x = rnd(seed, (8 * ct, hw, hw))
        got = pool.maxpool2(x)
        assert got.shape == (8 * ct, hw // 2, hw // 2)
        np.testing.assert_allclose(got, ref.maxpool2(x), rtol=1e-6)

    def test_picks_maxima(self):
        x = jnp.zeros((8, 4, 4)).at[:, 1, 1].set(9.0)
        out = pool.maxpool2(x)
        assert float(out[0, 0, 0]) == 9.0


class TestUpsample:
    def test_basic(self):
        x = rnd(1, (8, 4, 4))
        np.testing.assert_allclose(pool.upsample2(x), ref.upsample2(x), rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(hw=st.sampled_from([1, 2, 5, 8]), seed=st.integers(0, 2**16))
    def test_shape_sweep(self, hw, seed):
        x = rnd(seed, (8, hw, hw))
        got = pool.upsample2(x)
        assert got.shape == (8, hw * 2, hw * 2)
        np.testing.assert_allclose(got, ref.upsample2(x), rtol=1e-6)

    def test_pool_inverts_upsample(self):
        x = rnd(2, (8, 4, 4))
        np.testing.assert_allclose(pool.maxpool2(pool.upsample2(x)), x, rtol=1e-6)


class TestGap:
    def test_matches_mean(self):
        x = rnd(3, (16, 7, 7))
        got = pool.global_avg_pool(x)
        want = x.mean(axis=(1, 2))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
